"""Faithful Python port of the rust composable-layer backend (same RNG
streams, same call order) to pre-verify the module-system test assertions:
finite-difference gradient checks for PatchConv / LayerNorm / Attention,
Monte-Carlo unbiasedness of the sketched PatchConv backward, and the
BagNet-lite / ViT-lite convergence bars used by rust/tests.

Companion to native_sim.py (PR 1), which covers the MLP path.

Note: the rust side has since moved to a destination-passing kernel API
(layers write into workspace buffers instead of returning matrices —
DESIGN.md §7.2). The math, the per-element accumulation orders and the
gate-RNG call order are unchanged, so this simulator's numerics remain a
valid oracle for the rust assertions.

The activation-policy extension (DESIGN.md §7.4, `python module_sim.py
act`) additionally models the kept-column input stash: at every gated
sketch site the forward draws l2/correlated X-gates from a separate
stream and stores only the kept input columns; the backward then runs the
doubly-gated dW estimator. It pre-verifies the MC-unbiasedness margins
and the kept-policy convergence bars asserted in rust/tests/act_policy.rs
(and the native_train.rs parity bar under the CI `UAVJP_ACTPOLICY=kept`
leg).
"""
import math
import sys

import numpy as np

from native_sim import (
    Pcg64,
    column_scores,
    correlated_bernoulli,
    generate as generate_mnist,
    independent_bernoulli,
    pstar_from_weights,
    sketched_linear_backward,
)

F = np.float32


def dense_linear_backward(g, x, w, need_dx):
    dw = (g.T @ x).astype(F)
    db = g.sum(0).astype(F)
    dx = (g @ w).astype(F) if need_dx else None
    return dw, db, dx


# ---------------------------------------------------------------------------
# activation policy: kept-column input stash (rust native/policy.rs +
# layer.rs kept_linear_backward_into)
# ---------------------------------------------------------------------------
def act_plan_columns(x2d, budget, rng):
    """X-side gate plan: l2 column scores -> waterfilled p* -> correlated
    (systematic) gates. Returns [(col, 1/p_col)] for kept columns —
    mirrors rust SketchScratch::plan_columns with method \"l2\"."""
    sq = (x2d.astype(np.float64) ** 2).sum(0).astype(np.float32)
    p = pstar_from_weights(sq, budget * x2d.shape[1])
    z = correlated_bernoulli(rng, p)
    return [(j, np.float32(1.0 / p[j])) for j in range(len(p)) if z[j]]


def act_gather(x2d, budget, rng):
    """Forward-time stash: draw X-gates, keep only the selected columns.
    Returns the kept tuple stored in place of the full input cache."""
    kept = act_plan_columns(x2d, budget, rng)
    xg = x2d[:, [j for j, _ in kept]].copy() if kept else \
        np.zeros((x2d.shape[0], 0), F)
    return ("kept", xg, kept, x2d.shape[1])


def kept_linear_backward(g, xg, xkept, din, w, method, budget, rng, need_dx):
    """Doubly-gated backward over a kept-column stash (port of rust
    kept_linear_backward_into): dW = scatter(Ĝᵀ·X̂) with per-column 1/pₓ
    rescale; db and dX involve only the G-gates, so they match the
    singly-gated estimator exactly."""
    dout = g.shape[1]
    if method == "per_column":
        p = np.full(dout, F(min(max(budget, 1e-6), 1.0)), F)
    else:
        p = pstar_from_weights(column_scores(method, g, w), budget * dout)
    independent = method == "per_column" or method.endswith("_ind")
    z = independent_bernoulli(rng, p) if independent else \
        correlated_bernoulli(rng, p)
    inv = np.where(z, 1.0 / p, 0.0).astype(F)
    gh = (g * inv[None, :]).astype(F)
    dw_small = (gh.T @ xg).astype(F)  # [dout, m] — zero rows where z=0
    dw = np.zeros((dout, din), F)
    for c, (j, invx) in enumerate(xkept):
        dw[:, j] = dw_small[:, c] * invx
    db = gh.sum(0).astype(F)
    dx = (gh @ w).astype(F) if need_dx else None
    return dw, db, dx


def stash_linear_backward(g, x, w, sketch, rng, need_dx):
    """Dispatch a linear backward over a (possibly kept-column) stash —
    the python twin of rust linear_backward_stash."""
    if isinstance(x, tuple) and x and x[0] == "kept":
        _, xg, kept, din = x
        assert sketch is not None, "kept stash implies a gated site"
        return kept_linear_backward(
            g, xg, kept, din, w, sketch[0], sketch[1], rng, need_dx)
    if sketch is not None:
        return sketched_linear_backward(
            g, x, w, sketch[0], sketch[1], rng, need_dx)
    return dense_linear_backward(g, x, w, need_dx)


# ---------------------------------------------------------------------------
# layers — forward caches exactly what the rust Layer impls cache
# ---------------------------------------------------------------------------
def he_linear(din, dout, seed, stream):
    rng = Pcg64(seed ^ 0x1E57, stream)
    std = math.sqrt(2.0 / din)
    w = np.array(
        [F(rng.gaussian() * std) for _ in range(dout * din)], F
    ).reshape(dout, din)
    return [w, np.zeros(dout, F)]


def scaled_linear(din, dout, std, seed, stream):
    rng = Pcg64(seed ^ 0x1E57, stream)
    w = np.array(
        [F(rng.gaussian() * std) for _ in range(dout * din)], F
    ).reshape(dout, din)
    return [w, np.zeros(dout, F)]


class Linear:
    sketchable = True

    def __init__(self, din, dout, seed, stream, std=None):
        if std is None:
            self.w, self.b = he_linear(din, dout, seed, stream)
        else:
            self.w, self.b = scaled_linear(din, dout, std, seed, stream)

    def params(self):
        return [self.w, self.b]

    def set_params(self, ps):
        self.w, self.b = ps

    def forward(self, x):
        return (x @ self.w.T + self.b).astype(F), [x.copy()]

    def backward(self, gy, cache, sketch, rng, need_gx):
        dw, db, gx = stash_linear_backward(
            gy, cache[0], self.w, sketch, rng, need_gx)
        return gx, [dw, db]


class Relu:
    sketchable = False

    def params(self):
        return []

    def set_params(self, ps):
        pass

    def forward(self, x):
        return np.maximum(x, 0).astype(F), [x.copy()]

    def backward(self, gy, cache, sketch, rng, need_gx):
        gx = gy.copy()
        gx[cache[0] <= 0] = 0
        return gx, []


class Patchify:
    sketchable = False

    def __init__(self, h, w, c, q):
        self.h, self.w, self.c, self.q = h, w, c, q
        self.patches = (h // q) * (w // q)
        self.dp = q * q * c
        src = np.zeros(h * w * c, np.int64)
        j = 0
        for pr in range(h // q):
            for pc in range(w // q):
                for dr in range(q):
                    for dc in range(q):
                        for ch in range(c):
                            src[j] = ((pr * q + dr) * w + (pc * q + dc)) * c + ch
                            j += 1
        self.src = src

    def params(self):
        return []

    def set_params(self, ps):
        pass

    def forward(self, x):
        return x[:, self.src].astype(F), []

    def backward(self, gy, cache, sketch, rng, need_gx):
        gx = np.zeros_like(gy)
        gx[:, self.src] = gy
        return gx, []


class PatchConv:
    sketchable = True

    def __init__(self, patches, din, dout, seed, stream):
        self.p, self.din, self.dout = patches, din, dout
        self.w, self.b = he_linear(din, dout, seed, stream)

    def params(self):
        return [self.w, self.b]

    def set_params(self, ps):
        self.w, self.b = ps

    def forward(self, x):
        bsz = x.shape[0]
        xp = x.reshape(bsz * self.p, self.din)
        z = (xp @ self.w.T + self.b).astype(F)
        return z.reshape(bsz, self.p * self.dout), [xp.copy()]

    def backward(self, gy, cache, sketch, rng, need_gx):
        g = gy.reshape(-1, self.dout)
        dw, db, gx = stash_linear_backward(
            g, cache[0], self.w, sketch, rng, need_gx)
        if gx is not None:
            gx = gx.reshape(gy.shape[0], self.p * self.din)
        return gx, [dw, db]


class PatchMeanPool:
    sketchable = False

    def __init__(self, patches, dim):
        self.p, self.d = patches, dim

    def params(self):
        return []

    def set_params(self, ps):
        pass

    def forward(self, x):
        bsz = x.shape[0]
        return x.reshape(bsz, self.p, self.d).mean(1).astype(F), []

    def backward(self, gy, cache, sketch, rng, need_gx):
        scale = F(1.0 / self.p)
        gx = np.repeat((gy * scale)[:, None, :], self.p, axis=1)
        return gx.reshape(gy.shape[0], self.p * self.d).astype(F), []


class PosEmbed:
    sketchable = False

    def __init__(self, patches, dim, seed, stream):
        rng = Pcg64(seed ^ 0x1E57, stream)
        self.p, self.d = patches, dim
        self.table = np.array(
            [F(rng.gaussian() * 0.02) for _ in range(patches * dim)], F
        )

    def params(self):
        return [self.table]

    def set_params(self, ps):
        (self.table,) = ps

    def forward(self, x):
        return (x + self.table[None, :]).astype(F), []

    def backward(self, gy, cache, sketch, rng, need_gx):
        return gy.copy(), [gy.sum(0).astype(F)]


class LayerNorm:
    sketchable = False
    EPS = 1e-5

    def __init__(self, dim):
        self.d = dim
        self.gamma = np.ones(dim, F)
        self.beta = np.zeros(dim, F)

    def params(self):
        return [self.gamma, self.beta]

    def set_params(self, ps):
        self.gamma, self.beta = ps

    def forward(self, x):
        rows = x.reshape(-1, self.d)
        mu = rows.mean(1, keepdims=True).astype(F)
        var = ((rows - mu) ** 2).mean(1, keepdims=True).astype(F)
        invstd = (1.0 / np.sqrt(var + F(self.EPS))).astype(F)
        xhat = ((rows - mu) * invstd).astype(F)
        y = (self.gamma * xhat + self.beta).astype(F)
        return y.reshape(x.shape), [xhat.copy(), invstd.copy()]

    def backward(self, gy, cache, sketch, rng, need_gx):
        xhat, invstd = cache
        g = gy.reshape(-1, self.d)
        dgamma = (g * xhat).sum(0).astype(F)
        dbeta = g.sum(0).astype(F)
        gxhat = (g * self.gamma).astype(F)
        m1 = gxhat.mean(1, keepdims=True).astype(F)
        m2 = (gxhat * xhat).mean(1, keepdims=True).astype(F)
        gx = (invstd * (gxhat - m1 - xhat * m2)).astype(F)
        return gx.reshape(gy.shape), [dgamma, dbeta]


class FfnBlock:
    sketchable = True

    def __init__(self, dim, hidden, seed, stream0):
        self.d = dim
        self.w1, self.b1 = he_linear(dim, hidden, seed, stream0)
        self.w2, self.b2 = he_linear(hidden, dim, seed, stream0 + 1)

    def params(self):
        return [self.w1, self.b1, self.w2, self.b2]

    def set_params(self, ps):
        self.w1, self.b1, self.w2, self.b2 = ps

    def forward(self, x):
        xs = x.reshape(-1, self.d)
        h = (xs @ self.w1.T + self.b1).astype(F)
        hr = np.maximum(h, 0).astype(F)
        y = (hr @ self.w2.T + self.b2 + xs).astype(F)
        return y.reshape(x.shape), [xs.copy(), h, hr]

    def backward(self, gy, cache, sketch, rng, need_gx):
        xs, h, hr = cache
        g = gy.reshape(-1, self.d)
        if sketch is not None:
            dw2, db2, gh = sketched_linear_backward(
                g, hr, self.w2, sketch[0], sketch[1], rng, True)
        else:
            dw2, db2, gh = dense_linear_backward(g, hr, self.w2, True)
        gh = gh.copy()
        gh[h <= 0] = 0
        dw1, db1, gx1 = stash_linear_backward(
            gh, xs, self.w1, sketch, rng, need_gx)
        gx = (g + gx1).astype(F).reshape(gy.shape) if need_gx else None
        return gx, [dw1, db1, dw2, db2]


class Attention:
    sketchable = True

    def __init__(self, patches, dim, heads, seed, streams):
        self.p, self.d, self.h = patches, dim, heads
        self.dh = dim // heads
        std = math.sqrt(1.0 / dim)
        self.wq, self.bq = scaled_linear(dim, dim, std, seed, streams[0])
        self.wk, self.bk = scaled_linear(dim, dim, std, seed, streams[1])
        self.wv, self.bv = scaled_linear(dim, dim, std, seed, streams[2])
        self.wo, self.bo = scaled_linear(dim, dim, std, seed, streams[3])

    def params(self):
        return [self.wq, self.bq, self.wk, self.bk,
                self.wv, self.bv, self.wo, self.bo]

    def set_params(self, ps):
        (self.wq, self.bq, self.wk, self.bk,
         self.wv, self.bv, self.wo, self.bo) = ps

    def forward(self, x):
        bsz = x.shape[0]
        xs = x.reshape(bsz * self.p, self.d)
        q = (xs @ self.wq.T + self.bq).astype(F)
        k = (xs @ self.wk.T + self.bk).astype(F)
        v = (xs @ self.wv.T + self.bv).astype(F)
        scale = F(1.0 / math.sqrt(self.dh))
        o = np.zeros_like(q)
        attn = []
        for b in range(bsz):
            rows = slice(b * self.p, (b + 1) * self.p)
            for h in range(self.h):
                cols = slice(h * self.dh, (h + 1) * self.dh)
                s = (q[rows, cols] @ k[rows, cols].T * scale).astype(F)
                m = s.max(1, keepdims=True)
                e = np.exp((s - m).astype(F)).astype(F)
                a = (e / e.sum(1, keepdims=True)).astype(F)
                attn.append(a)
                o[rows, cols] = (a @ v[rows, cols]).astype(F)
        y = (o @ self.wo.T + self.bo + xs).astype(F)
        return y.reshape(bsz, self.p * self.d), [xs.copy(), q, k, v, o, attn]

    def backward(self, gy, cache, sketch, rng, need_gx):
        xs, q, k, v, o, attn = cache
        bsz = gy.shape[0]
        g = gy.reshape(bsz * self.p, self.d)
        if sketch is not None:
            dwo, dbo, go = sketched_linear_backward(
                g, o, self.wo, sketch[0], sketch[1], rng, True)
        else:
            dwo, dbo, go = dense_linear_backward(g, o, self.wo, True)
        gx = g.copy()  # residual
        gq = np.zeros_like(q)
        gk = np.zeros_like(k)
        gv = np.zeros_like(v)
        scale = F(1.0 / math.sqrt(self.dh))
        for b in range(bsz):
            rows = slice(b * self.p, (b + 1) * self.p)
            for h in range(self.h):
                cols = slice(h * self.dh, (h + 1) * self.dh)
                a = attn[b * self.h + h]
                goh = go[rows, cols]
                ga = (goh @ v[rows, cols].T).astype(F)
                gv[rows, cols] = (a.T @ goh).astype(F)
                rowdot = (ga * a).sum(1, keepdims=True).astype(F)
                gs = (a * (ga - rowdot)).astype(F)
                gq[rows, cols] = (gs @ k[rows, cols] * scale).astype(F)
                gk[rows, cols] = (gs.T @ q[rows, cols] * scale).astype(F)
        grads = []
        for gmat, w in [(gq, self.wq), (gk, self.wk), (gv, self.wv)]:
            dw, db, gxi = stash_linear_backward(
                gmat, xs, w, sketch, rng, need_gx)
            grads.append((dw, db))
            if need_gx:
                gx = (gx + gxi).astype(F)
        (dwq, dbq), (dwk, dbk), (dwv, dbv) = grads
        gxout = gx.reshape(bsz, self.p * self.d) if need_gx else None
        return gxout, [dwq, dbq, dwk, dbk, dwv, dbv, dwo, dbo]


# ---------------------------------------------------------------------------
# sequential + models + trainer (mirrors rust/src/native/{sequential,models})
# ---------------------------------------------------------------------------
def bagnet(seed):
    return [
        Patchify(32, 32, 3, 8),
        PatchConv(16, 192, 64, seed, 300),
        Relu(),
        PatchConv(16, 64, 64, seed, 301),
        Relu(),
        PatchMeanPool(16, 64),
        Linear(64, 10, seed, 302),
    ]


def vit(seed):
    return [
        Patchify(32, 32, 3, 8),
        PatchConv(16, 192, 64, seed, 300),
        PosEmbed(16, 64, seed, 301),
        Attention(16, 64, 4, seed, [302, 303, 304, 305]),
        LayerNorm(64),
        FfnBlock(64, 128, seed, 306),
        LayerNorm(64),
        PatchMeanPool(16, 64),
        Linear(64, 10, seed, 308),
    ]


def bagnet_deep(seed):
    """2x-deep BagNet-lite (rust models::bagnet_deep): four conv stages."""
    return [
        Patchify(32, 32, 3, 8),
        PatchConv(16, 192, 64, seed, 300),
        Relu(),
        PatchConv(16, 64, 64, seed, 301),
        Relu(),
        PatchConv(16, 64, 64, seed, 302),
        Relu(),
        PatchConv(16, 64, 64, seed, 303),
        Relu(),
        PatchMeanPool(16, 64),
        Linear(64, 10, seed, 304),
    ]


def vit_deep(seed):
    """3-block ViT-lite (rust models::vit_deep): encoder k uses streams
    302+6k .. 302+6k+5, classifier stream 320."""
    layers = [
        Patchify(32, 32, 3, 8),
        PatchConv(16, 192, 64, seed, 300),
        PosEmbed(16, 64, seed, 301),
    ]
    for k in range(3):
        s = 302 + 6 * k
        layers += [
            Attention(16, 64, 4, seed, [s, s + 1, s + 2, s + 3]),
            LayerNorm(64),
            FfnBlock(64, 128, seed, s + 4),
            LayerNorm(64),
        ]
    layers += [PatchMeanPool(16, 64), Linear(64, 10, seed, 320)]
    return layers


def mlp_layers(dims, seed):
    """MLP with the rust models::mlp streams (Linear li on stream 300+li)."""
    layers = []
    n = len(dims) - 1
    for li in range(n):
        layers.append(Linear(dims[li], dims[li + 1], seed, 300 + li))
        if li + 1 < n:
            layers.append(Relu())
    return layers


MODELS = {"bagnet": bagnet, "vit": vit,
          "bagnet_deep": bagnet_deep, "vit_deep": vit_deep}


def seq_forward(layers, x, plan=None, act_budget=None, act_rng=None):
    """Forward pass; when `act_budget` is set, every gated sketch site's
    input cache is replaced by its kept-column stash (gates drawn in
    forward order from the dedicated act stream, as in rust
    Sequential::forward_train). act_budget<=0 inherits the site's sketch
    budget (ActivationPolicy \"kept\" with no explicit budget)."""
    caches = []
    h = x
    for i, layer in enumerate(layers):
        nxt, c = layer.forward(h)
        if (act_budget is not None and plan is not None
                and plan[i] is not None and layer.sketchable and c):
            b_act = act_budget if act_budget > 0 else plan[i][1]
            c[0] = act_gather(c[0], b_act, act_rng)
        caches.append(c)
        h = nxt
    return h, caches


def seq_backward(layers, caches, dout, plan, rng):
    grads = [None] * len(layers)
    g = dout
    for i in range(len(layers) - 1, -1, -1):
        need_gx = i > 0
        gx, pg = layers[i].backward(g, caches[i], plan[i], rng, need_gx)
        grads[i] = pg
        if need_gx:
            g = gx
    return grads


def make_plan(layers, method, budget, location):
    sites = [i for i, l in enumerate(layers) if l.sketchable]
    mask = [False] * len(sites)
    if location == "all":
        mask = [True] * len(sites)
    elif location == "first":
        mask[0] = True
    elif location == "last":
        mask[-1] = True
    plan = [None] * len(layers)
    if method != "baseline":
        for si, li in enumerate(sites):
            if mask[si]:
                plan[li] = (method, budget)
    return plan


def ce_loss_grad(logits, y):
    m = logits.max(1, keepdims=True)
    e = np.exp((logits - m).astype(F))
    sm = e / e.sum(1, keepdims=True)
    bsz = len(y)
    loss = -np.log(np.maximum(sm[np.arange(bsz), y], 1e-12)).mean()
    g = sm.copy()
    g[np.arange(bsz), y] -= 1.0
    return float(loss), (g / bsz).astype(F)


def clip_all(grads, maxn=1.0):
    sq = 0.0
    for pg in grads:
        for t in pg:
            sq += float((t.astype(np.float64) ** 2).sum())
    norm = math.sqrt(sq)
    if norm > maxn:
        s = F(maxn / max(norm, 1e-12))
        grads = [[t * s for t in pg] for pg in grads]
    return grads


class Momentum:
    def __init__(self, mu):
        self.mu = F(mu)
        self.vel = {}

    def update(self, slot, p, g, lr):
        v = self.vel.get(slot)
        if v is None:
            v = np.zeros_like(p)
        v = (self.mu * v + g).astype(F)
        self.vel[slot] = v
        return (p - F(lr) * v).astype(F)


class Adam:
    def __init__(self):
        self.m, self.v, self.t = {}, {}, {}

    def update(self, slot, p, g, lr):
        t = self.t.get(slot, 0.0) + 1.0
        self.t[slot] = t
        m = self.m.get(slot, np.zeros_like(p))
        v = self.v.get(slot, np.zeros_like(p))
        m = (F(0.9) * m + F(0.1) * g).astype(F)
        v = (F(0.999) * v + F(0.001) * g * g).astype(F)
        self.m[slot], self.v[slot] = m, v
        bc1 = F(1.0 - 0.9 ** t)
        bc2 = F(1.0 - 0.999 ** t)
        mhat = m / bc1
        vhat = v / bc2
        return (p - F(lr) * mhat / (np.sqrt(vhat) + F(1e-8))).astype(F)


def lr_at(base_lr, step, steps, warmup, cosine):
    if warmup > 0 and step < warmup:
        return base_lr * (step + 1) / warmup
    if cosine:
        t = (step - warmup) / max(steps - warmup, 1)
        floor = 0.01 * base_lr
        return floor + (base_lr - floor) * 0.5 * (1.0 + math.cos(math.pi * t))
    return base_lr


# ---------------------------------------------------------------------------
# synth-CIFAR generator (port of rust/src/data sample_cifar path)
# ---------------------------------------------------------------------------
def cifar_anchors(seed):
    anchors = []
    for cls in range(10):
        rng = Pcg64(seed ^ 0xC1FA, 200 + cls)
        img = np.zeros(32 * 32 * 3, F)
        color = [rng.f32(), rng.f32(), rng.f32()]
        fx = 1.0 + rng.below(4)
        fy = 1.0 + rng.below(4)
        phase = rng.f32() * np.float32(6.28)
        blobs = [
            (rng.f32() * np.float32(32.0), rng.f32() * np.float32(32.0),
             np.float32(4.0) + rng.f32() * np.float32(6.0))
            for _ in range(3)
        ]
        for r in range(32):
            for c in range(32):
                stripes = F(math.sin(
                    (fx * F(r) / F(32.0) + fy * F(c) / F(32.0)) * F(6.28)
                    + phase) * 0.3)
                blob = F(0.0)
                for br, bc, rad in blobs:
                    d2 = (F(r) - br) ** 2 + (F(c) - bc) ** 2
                    blob = F(blob + math.exp(-d2 / (rad * rad)))
                for ch in range(3):
                    img[(r * 32 + c) * 3 + ch] = F(
                        color[ch] * min(F(0.4) + blob, F(1.2)) + stripes)
        anchors.append(img)
    return anchors


def generate_cifar(n, seed, split):
    stream = 1 if split == "train" else 2
    rng = Pcg64(seed, stream)
    anchors = cifar_anchors(seed)
    x = np.zeros((n, 3072), F)
    y = np.zeros(n, np.int64)
    for i in range(n):
        cls = rng.below(10)
        y[i] = cls
        a = anchors[cls]
        white = np.array([F(rng.gaussian()) for _ in range(32 * 32)], F)
        flip = rng.bernoulli(0.5)
        bright = F(0.85) + F(0.3) * rng.f32()
        row = np.zeros(3072, F)
        wg = white.reshape(32, 32)
        for r in range(32):
            for c in range(32):
                r0, r1 = max(r - 1, 0), min(r + 1, 31)
                c0, c1 = max(c - 1, 0), min(c + 1, 31)
                box = wg[r0:r1 + 1, c0:c1 + 1]
                noise = F(box.sum() / box.size * 0.35)
                src_c = 31 - c if flip else c
                for ch in range(3):
                    row[(r * 32 + c) * 3 + ch] = F(
                        min(max(a[(r * 32 + src_c) * 3 + ch] * bright + noise,
                                F(-1.0)), F(2.0)))
        x[i] = row
    return x, y


def run_trainer(layers, xtr, ytr, xte, yte, plan, opt, lr, steps, batch,
                warmup=0, cosine=False, seed=0, act_budget=None):
    sk_rng = Pcg64(seed ^ 0x9E3779B9, 11)
    act_rng = Pcg64(seed ^ 0x51AC7, 13)
    rng = Pcg64(seed + 77, 3)
    losses = []
    step = 0
    n = len(xtr)
    while step < steps:
        order = list(range(n))
        rng.shuffle(order)
        cursor = 0
        while cursor + batch <= n and step < steps:
            idx = order[cursor:cursor + batch]
            cursor += batch
            xb, yb = xtr[idx], ytr[idx]
            out, caches = seq_forward(layers, xb, plan, act_budget, act_rng)
            loss, dl = ce_loss_grad(out, yb)
            grads = seq_backward(layers, caches, dl, plan, sk_rng)
            grads = clip_all(grads)
            cur_lr = lr_at(lr, step, steps, warmup, cosine)
            slot = 0
            for li, layer in enumerate(layers):
                ps = layer.params()
                new_ps = []
                for t, g in zip(ps, grads[li]):
                    new_ps.append(opt.update(slot, t, g, cur_lr))
                    slot += 1
                layer.set_params(new_ps)
            losses.append(loss)
            step += 1
    nb = len(xte) // batch
    lsum = 0.0
    correct = 0.0
    for b in range(nb):
        xb = xte[b * batch:(b + 1) * batch]
        yb = yte[b * batch:(b + 1) * batch]
        out, _ = seq_forward(layers, xb)
        l, _ = ce_loss_grad(out, yb)
        lsum += l * batch
        correct += (out.argmax(1) == yb).sum()
    return losses, lsum / (nb * batch), correct / (nb * batch)


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------
def fd_check_layer(layer, x, eps=1e-4, tol=2e-4):
    """f64 finite-difference check of layer.backward against a random
    projection loss L = sum(out * R)."""
    rng = np.random.default_rng(0)
    out, cache = layer.forward(x)
    r = rng.standard_normal(out.shape).astype(F)
    gx, pgrads = layer.backward(r, cache, None, None, True)
    worst = 0.0
    # input gradient
    for idx in [0, x.size // 3, x.size - 1]:
        i, j = divmod(idx, x.shape[1])
        orig = x[i, j]
        x[i, j] = orig + eps
        lp = float((layer.forward(x)[0].astype(np.float64) * r).sum())
        x[i, j] = orig - eps
        lm = float((layer.forward(x)[0].astype(np.float64) * r).sum())
        x[i, j] = orig
        fd = (lp - lm) / (2 * eps)
        an = float(gx[i, j])
        worst = max(worst, abs(fd - an) / (1.0 + abs(fd)))
    # parameter gradients
    for ti, t in enumerate(layer.params()):
        flat = t.reshape(-1)
        for idx in [0, flat.size // 2, flat.size - 1]:
            orig = flat[idx]
            flat[idx] = orig + eps
            lp = float((layer.forward(x)[0].astype(np.float64) * r).sum())
            flat[idx] = orig - eps
            lm = float((layer.forward(x)[0].astype(np.float64) * r).sum())
            flat[idx] = orig
            fd = (lp - lm) / (2 * eps)
            an = float(pgrads[ti].reshape(-1)[idx])
            worst = max(worst, abs(fd - an) / (1.0 + abs(fd)))
    return worst


def check_fd():
    rng = np.random.default_rng(7)
    print("== finite-difference checks (f32 forward, eps per layer) ==")
    x = rng.standard_normal((3, 4 * 6)).astype(F)
    worst = fd_check_layer(PatchConv(4, 6, 5, 1, 300), x, eps=1e-2)
    print(f"  PatchConv  worst rel dev: {worst:.2e}")
    assert worst < 5e-3, worst
    x = rng.standard_normal((3, 4 * 6)).astype(F)
    worst = fd_check_layer(LayerNorm(6), x, eps=1e-2)
    print(f"  LayerNorm  worst rel dev: {worst:.2e}")
    assert worst < 5e-3, worst
    x = (rng.standard_normal((2, 4 * 8)) * 0.5).astype(F)
    worst = fd_check_layer(Attention(4, 8, 2, 1, [302, 303, 304, 305]), x,
                           eps=1e-2)
    print(f"  Attention  worst rel dev: {worst:.2e}")
    assert worst < 5e-3, worst
    x = rng.standard_normal((2, 4 * 6)).astype(F)
    worst = fd_check_layer(FfnBlock(6, 10, 1, 306), x, eps=1e-2)
    print(f"  FfnBlock   worst rel dev: {worst:.2e}")
    assert worst < 5e-3, worst
    x = rng.standard_normal((2, 4 * 6)).astype(F)
    worst = fd_check_layer(PosEmbed(4, 6, 1, 301), x, eps=1e-2)
    print(f"  PosEmbed   worst rel dev: {worst:.2e}")
    assert worst < 5e-3, worst
    x = rng.standard_normal((2, 2 * 2 * 3 * 4)).astype(F)
    worst = fd_check_layer(Patchify(4, 4, 3, 2), x, eps=1e-2)
    print(f"  Patchify   worst rel dev: {worst:.2e}")
    assert worst < 5e-3, worst
    x = rng.standard_normal((2, 4 * 6)).astype(F)
    worst = fd_check_layer(PatchMeanPool(4, 6), x, eps=1e-2)
    print(f"  MeanPool   worst rel dev: {worst:.2e}")
    assert worst < 5e-3, worst


def check_patchconv_unbiased(method="l1", budget=0.45, trials=2500):
    print(f"== MC unbiasedness: sketched PatchConv ({method} p={budget}, "
          f"{trials} trials) ==")
    layer = PatchConv(4, 6, 12, 3, 300)
    rng_data = Pcg64(3, 0)
    x = np.array([F(rng_data.gaussian()) for _ in range(4 * 4 * 6)],
                 F).reshape(4, 24)
    out, cache = layer.forward(x)
    gy = np.array([F(rng_data.gaussian()) for _ in range(out.size)],
                  F).reshape(out.shape)
    gx_e, (dw_e, db_e) = layer.backward(gy, cache, None, None, True)
    acc_dw = np.zeros(dw_e.shape, np.float64)
    acc_db = np.zeros(db_e.shape, np.float64)
    acc_gx = np.zeros(gx_e.shape, np.float64)
    gate_rng = Pcg64(3 ^ 0x5EED, 1)
    for _ in range(trials):
        gx, (dw, db) = layer.backward(gy, cache, (method, budget), gate_rng,
                                      True)
        acc_dw += dw
        acc_db += db
        acc_gx += gx
    def rel(acc, exact):
        d = acc / trials - exact.astype(np.float64)
        return math.sqrt(float((d ** 2).sum()) /
                         max(float((exact.astype(np.float64) ** 2).sum()),
                             1e-12))
    rdw, rdb, rgx = rel(acc_dw, dw_e), rel(acc_db, db_e), rel(acc_gx, gx_e)
    print(f"  rel MC dev: dW {rdw:.4f}  db {rdb:.4f}  dX {rgx:.4f}")
    return rdw, rdb, rgx


def check_training(model_name, steps, opt_name, lr, warmup, budget_runs,
                   batch=32):
    """Each run is (method, budget) — full input caches — or
    (method, budget, act_budget) — kept-column stashes at gated sites
    (act_budget 0.0 inherits the sketch budget)."""
    print(f"== {model_name} training (steps={steps}, {opt_name} lr={lr}) ==")
    xtr, ytr = DATA["train"]
    xte, yte = DATA["test"]
    results = {}
    for run in budget_runs:
        method, budget = run[0], run[1]
        act = run[2] if len(run) > 2 else None
        layers = MODELS[model_name](0)
        plan = make_plan(layers, method, budget,
                         "all" if method != "baseline" else "none")
        opt = Momentum(0.9) if opt_name == "momentum" else Adam()
        losses, el, ea = run_trainer(
            layers, xtr, ytr, xte, yte, plan, opt, lr, steps, batch,
            warmup=warmup, cosine=True, seed=0, act_budget=act)
        tail = sum(losses[-8:]) / 8
        tag = f"{method} p={budget}" + ("" if act is None else
                                        f" act={act if act else budget}")
        print(f"  {tag:>22}: loss {losses[0]:.3f} -> tail {tail:.3f}, "
              f"eval loss {el:.3f}, acc {ea:.3f}")
        results[run] = (losses[0], tail, el, ea)
    return results


def check_kept_unbiased(g_method="l1", g_budget=0.4, x_budget=0.5,
                        trials=4000, rescale=True):
    """MC check of the doubly-gated kept-stash backward against the exact
    dense backward (same shapes/budgets as the rust act_policy.rs MC
    tests). rescale=False drops the 1/p_x scatter factor — the negative
    control: dW must then miss the bar while db/dX (G-gated only) still
    pass."""
    tag = "" if rescale else ", NO 1/px rescale (negative control)"
    print(f"== MC unbiasedness: kept stash (G {g_method} p={g_budget}, "
          f"X l2 p={x_budget}, {trials} trials{tag}) ==")
    b, dout, din = 8, 12, 6
    rng_data = Pcg64(42, 0)
    def gauss(r, c, scale=1.0):
        return np.array([F(rng_data.gaussian() * scale)
                         for _ in range(r * c)], F).reshape(r, c)
    x = gauss(b, din)
    g = gauss(b, dout)
    w = gauss(dout, din, 0.5)
    dw_e, db_e, dx_e = dense_linear_backward(g, x, w, True)
    acc_dw = np.zeros(dw_e.shape, np.float64)
    acc_db = np.zeros(db_e.shape, np.float64)
    acc_dx = np.zeros(dx_e.shape, np.float64)
    g_rng = Pcg64(7, 1)
    x_rng = Pcg64(9, 2)
    for _ in range(trials):
        kept = act_plan_columns(x, x_budget, x_rng)
        if not rescale:
            kept = [(j, np.float32(1.0)) for j, _ in kept]
        xg = x[:, [j for j, _ in kept]].copy()
        dw, db, dx = kept_linear_backward(
            g, xg, kept, din, w, g_method, g_budget, g_rng, True)
        acc_dw += dw
        acc_db += db
        acc_dx += dx
    def rel(acc, exact):
        d = acc / trials - exact.astype(np.float64)
        return math.sqrt(float((d ** 2).sum()) /
                         max(float((exact.astype(np.float64) ** 2).sum()),
                             1e-12))
    rdw, rdb, rdx = rel(acc_dw, dw_e), rel(acc_db, db_e), rel(acc_dx, dx_e)
    print(f"  rel MC dev: dW {rdw:.4f}  db {rdb:.4f}  dX {rdx:.4f}")
    return rdw, rdb, rdx


def check_mlp_kept_bar():
    """native_train.rs sketched_l1_budget_quarter_tracks_exact under the
    CI kept leg (UAVJP_ACTPOLICY=kept): the doubly-gated mlp run must
    still meet `sketched <= exact*1.10 + 0.05` and acc > 0.8."""
    print("== mlp parity bar under kept policy (320 steps, sgd lr=0.1) ==")
    xtr, ytr = generate_mnist(1024, 1234, "train")
    xte, yte = generate_mnist(512, 1234, "test")
    dims = [784, 64, 10]

    def run(method, budget, act):
        layers = mlp_layers(dims, 0)
        plan = make_plan(layers, method, budget,
                         "all" if method != "baseline" else "none")
        # Momentum(0.0) == plain sgd, the mlp recipe optimizer
        return run_trainer(layers, xtr, ytr, xte, yte, plan, Momentum(0.0),
                           0.1, 320, 64, seed=0, act_budget=act)

    _, exact, eacc = run("baseline", 1.0, None)
    _, single, sacc = run("l1", 0.25, None)
    _, double, dacc = run("l1", 0.25, 0.0)  # act budget inherits 0.25
    bar = exact * 1.10 + 0.05
    print(f"  exact        : eval {exact:.4f}  acc {eacc:.3f}")
    print(f"  l1@0.25      : eval {single:.4f}  acc {sacc:.3f}")
    print(f"  + kept@0.25  : eval {double:.4f}  acc {dacc:.3f}  "
          f"(bar {bar:.4f} -> {'PASS' if double <= bar and dacc > 0.8 else 'FAIL'})")
    return exact, single, double, dacc


DATA = {}

if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("fd", "all"):
        check_fd()
    if which in ("mc", "all"):
        check_patchconv_unbiased("l1", 0.45)
        check_patchconv_unbiased("l1_ind", 0.45)
        check_patchconv_unbiased("per_column", 0.5)
    if which in ("train", "all"):
        print("generating synth-CIFAR (pure-python PCG64, ~1 min)...")
        DATA["train"] = generate_cifar(256, 1234, "train")
        DATA["test"] = generate_cifar(128, 1234, "test")
        check_training("bagnet", 60, "momentum", 0.032, 0,
                       [("baseline", 1.0), ("l1", 0.25)])
        check_training("vit", 80, "adam", 1e-3, 8,
                       [("baseline", 1.0), ("l1", 0.25)])
    if which in ("act", "all"):
        # pillar 2 margins for rust/tests/act_policy.rs (tol 0.12)
        check_kept_unbiased("l1", 0.4, 0.5, 4000)
        check_kept_unbiased("l1_ind", 0.4, 0.5, 4000)
        check_kept_unbiased("l1", 0.4, 0.5, 1500, rescale=False)
        if "train" not in DATA:
            print("generating synth-CIFAR (pure-python PCG64, ~1 min)...")
            DATA["train"] = generate_cifar(256, 1234, "train")
            DATA["test"] = generate_cifar(128, 1234, "test")
        # shallow models: doubly-gated @0.25 vs the ISSUE convergence bars
        check_training("bagnet", 60, "momentum", 0.032, 0,
                       [("l1", 0.25), ("l1", 0.25, 0.0)])
        check_training("vit", 80, "adam", 1e-3, 8,
                       [("l1", 0.25), ("l1", 0.25, 0.0)])
        # deep variants at the act_policy.rs smoke-test settings
        check_training("bagnet_deep", 48, "momentum", 0.032, 0,
                       [("l1", 0.25, 0.0)], batch=16)
        check_training("vit_deep", 48, "adam", 1e-3, 8,
                       [("l1", 0.25, 0.0)], batch=16)
        # CI kept-leg: the existing mlp parity bar must survive dual gating
        check_mlp_kept_bar()
