#!/usr/bin/env python3
"""Python mirror of rust/src/analyze — used to pre-verify (no rust toolchain
in the authoring container) that the Rust analyzer's passes land green on the
real tree, and to enumerate violations that need fixing. Semantics mirror
rust/src/analyze/{scan,passes}.rs one-for-one; keep them in sync.
"""
import os
import re
import sys

# --- config (mirror of analyze::config) -----------------------------------
UNSAFE_ALLOWLIST = [
    "src/tensor/kernels/gemm.rs",
    "src/tensor/kernels/vec.rs",
    "src/tensor/kernels/lane.rs",
    "src/lib.rs",
    "tests/alloc_discipline.rs",
]
DET_MODULES = [
    "src/tensor/", "src/native/", "src/sketch/", "src/replicate/",
    "src/data/", "src/rng/", "src/faults/", "src/pool/",
]
DET_BANNED = ["HashMap", "HashSet", "Instant", "SystemTime"]
HOT_FILES = [
    "src/tensor/kernels/gemm.rs",
    "src/tensor/kernels/vec.rs",
    "src/tensor/kernels/lane.rs",
]
HOT_FNS = {
    "src/native/trainer.rs": ["step"],
    "src/native/sequential.rs": [
        "forward", "forward_train", "backward", "apply_grads",
        "retarget_batch",
    ],
    "src/replicate/mod.rs": [
        "step", "step_faulted", "reduce_into", "accumulate_stats",
    ],
    "src/serve/engine.rs": ["infer_batch", "infer_staged", "infer_one"],
    "src/native/loss.rs": ["loss_and_grad_into", "loss_and_grad_scaled_into"],
    "src/tensor/mod.rs": ["gemm_into", "sparse_dx_into", "sparse_dw_into"],
}
ALLOC_TOKENS = [
    "Vec::new", "vec!", "with_capacity", "to_vec", ".clone(", ".push(",
    "Box::new", "format!", "to_string", "String::new", ".collect(",
    "to_owned",
]
ALLOW_KINDS = ["rng", "unsafe", "nondet", "alloc"]
# registry mirror: (name, mix_kind, mix_const, lo, hi)
REGISTRY = [
    ("data-split",        "raw", 0,          1, 2),
    ("train-batch",       "add", 77,         3, 3),
    ("sketch-gates",      "xor", 0x9e3779b9, 11, 11),
    ("act-gates",         "xor", 0x51AC7,    13, 13),
    ("faults",            "xor", 0xFA0175,   17, 17),
    ("mnist-anchor",      "xor", 0xA17C,     100, 109),
    ("cifar-anchor",      "xor", 0xC1FA,     200, 209),
    ("layer-init",        "xor", 0x1E57,     300, 999),
    ("lane-sketch-gates", "xor", 0x9e3779b9, 1100, 1107),
    ("lane-act-gates",    "xor", 0x51AC7,    1300, 1307),
    ("variance-trial",    "xor", 0xABCD,     0, 4095),
    ("null",              "fixed", 0,        0, 0),
    ("ptest",             "raw", 0,          0x9E37, 0x9E37),
]


# --- scanner ----------------------------------------------------------------
def sanitize(text):
    """Split each line into (code, comment): literal contents blanked, comment
    text removed from code but kept aside for SAFETY/allow detection."""
    code_lines, comment_lines = [], []
    code, comment = [], []
    i, n = 0, len(text)
    mode = "normal"  # normal|line_comment|block_comment|string|raw_string
    block_depth = 0
    raw_hashes = 0
    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if c == "\n":
            code_lines.append("".join(code))
            comment_lines.append("".join(comment))
            code, comment = [], []
            if mode == "line_comment":
                mode = "normal"
            i += 1
            continue
        if mode == "line_comment":
            comment.append(c)
            i += 1
        elif mode == "block_comment":
            comment.append(c)
            if c == "/" and nxt == "*":
                block_depth += 1
                comment.append(nxt)
                i += 2
            elif c == "*" and nxt == "/":
                block_depth -= 1
                comment.append(nxt)
                i += 2
                if block_depth == 0:
                    mode = "normal"
            else:
                i += 1
        elif mode == "string":
            if c == "\\":
                if nxt == "\n":
                    code_lines.append("".join(code))
                    comment_lines.append("".join(comment))
                    code, comment = [], []
                i += 2
            elif c == '"':
                code.append('"')
                mode = "normal"
                i += 1
            else:
                i += 1
        elif mode == "raw_string":
            if c == '"' and text[i + 1:i + 1 + raw_hashes] == "#" * raw_hashes:
                code.append('"')
                mode = "normal"
                i += 1 + raw_hashes
            else:
                i += 1
        else:  # normal
            if c == "/" and nxt == "/":
                comment.append("//")
                mode = "line_comment"
                i += 2
            elif c == "/" and nxt == "*":
                comment.append("/*")
                mode = "block_comment"
                block_depth = 1
                i += 2
            elif c == '"':
                code.append('"')
                mode = "string"
                i += 1
            elif c == "r" and (nxt == '"' or nxt == "#") and not (
                code and (code[-1].isalnum() or code[-1] == "_")
            ):
                j = i + 1
                h = 0
                while j < n and text[j] == "#":
                    h += 1
                    j += 1
                if j < n and text[j] == '"':
                    code.append('r"')
                    raw_hashes = h
                    mode = "raw_string"
                    i = j + 1
                else:
                    code.append(c)
                    i += 1
            elif c == "'":
                m = re.match(r"'(\\.[^']*|[^'\\])'", text[i:])
                if m:
                    code.append("' '")
                    i += len(m.group(0))
                else:
                    code.append(c)  # lifetime tick
                    i += 1
            else:
                code.append(c)
                i += 1
    if code or comment:
        code_lines.append("".join(code))
        comment_lines.append("".join(comment))
    return code_lines, comment_lines


def depths(code_lines):
    """Brace depth *before* each line."""
    out = []
    d = 0
    for ln in code_lines:
        out.append(d)
        d += ln.count("{") - ln.count("}")
    return out


def test_regions(code_lines):
    """Bool per line: inside a #[cfg(test)] mod region."""
    n = len(code_lines)
    is_test = [False] * n
    dep = depths(code_lines)
    i = 0
    while i < n:
        if re.search(r"#\[cfg\((all\()?\s*test", code_lines[i]):
            j = i + 1
            while j < n and (
                code_lines[j].strip() == ""
                or code_lines[j].strip().startswith("#[")
            ):
                j += 1
            if j < n and re.match(r"\s*(pub\s+)?mod\b", code_lines[j]):
                d0 = dep[j]
                k = j
                d = d0
                while k < n:
                    is_test[k] = True
                    d = dep[k] + code_lines[k].count("{") - code_lines[k].count("}")
                    if k > j or "{" in code_lines[k]:
                        if d <= d0 and "{" in "".join(code_lines[j:k + 1]):
                            break
                    k += 1
                i = k + 1
                continue
            elif j < n:
                is_test[j] = True
                i = j + 1
                continue
        i += 1
    return is_test


def fn_regions(code_lines, names):
    """Bool per line: inside the body of a fn whose name is in `names`."""
    n = len(code_lines)
    hot = [False] * n
    for i, ln in enumerate(code_lines):
        m = re.search(r"\bfn\s+(\w+)", ln)
        if not m or m.group(1) not in names:
            continue
        # find opening brace from this line on
        d = 0
        opened = False
        k = i
        while k < n:
            for ch in code_lines[k]:
                if ch == "{":
                    d += 1
                    opened = True
                elif ch == "}":
                    d -= 1
            hot[k] = True
            if opened and d <= 0:
                break
            k += 1
    return hot


def word_in(tok, line):
    return re.search(r"(?<![A-Za-z0-9_])" + re.escape(tok) + r"(?![A-Za-z0-9_])", line)


def has_allow(kind, code_lines, comment_lines, i):
    """An allow comment covers its own line (trailing) and, when placed on
    its own line, the remainder of the statement that follows it — the
    walk back from the finding stops at the first earlier line ending in
    a statement/block terminator (`;`, `{`, `}`), capped at 12 lines."""
    for j in range(i, max(-1, i - 13), -1):
        m = re.search(r"analyze:\s*allow\((\w+),\s*[^)]+\)", comment_lines[j])
        if m and m.group(1) == kind:
            return True
        if j < i and code_lines[j].rstrip()[-1:] in (";", "{", "}"):
            break
    return False


def parse_rng_args(args):
    """(mix_kind, mix_const, stream) with None for unparseable parts."""
    parts = split_top(args)
    if len(parts) > 1 and parts[-1].strip() == "":
        parts = parts[:-1]  # trailing comma in a multi-line call
    if len(parts) != 2:
        return None, None, None
    seed, stream = parts[0].strip(), parts[1].strip()
    mix = None
    const = None
    m = re.match(r".*\^\s*(0x[0-9a-fA-F_]+|\d+)\s*$", seed)
    if m:
        mix, const = "xor", int(m.group(1).replace("_", ""), 0)
    elif re.match(r"^.*\.wrapping_add\((\d+)\)$", seed):
        mix = "add"
        const = int(re.match(r"^.*\.wrapping_add\((\d+)\)$", seed).group(1))
    elif re.match(r"^(0x[0-9a-fA-F_]+|\d+)$", seed):
        mix, const = "fixed", int(seed.replace("_", ""), 0)
    elif re.match(r"^[\w.]+$", seed):
        mix, const = "raw", 0
    sid = None
    m = re.match(r"^(0x[0-9a-fA-F_]+|\d+)$", stream)
    if m:
        sid = int(m.group(1).replace("_", ""), 0)
    else:
        m = re.match(r"^(\d+)\s*\+", stream)
        if m:
            sid = int(m.group(1))
    return mix, const, sid


def split_top(s):
    parts, d, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            d += 1
        elif ch in ")]}":
            d -= 1
        if ch == "," and d == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return parts


def extract_call(code_lines, i, col):
    """Balanced-paren arg text of a call starting at line i, col of '('."""
    buf = []
    d = 0
    k = i
    pos = col
    while k < len(code_lines):
        ln = code_lines[k]
        while pos < len(ln):
            ch = ln[pos]
            if ch == "(":
                d += 1
                if d == 1:
                    pos += 1
                    continue
            elif ch == ")":
                d -= 1
                if d == 0:
                    return "".join(buf)
            if d >= 1:
                buf.append(ch)
            pos += 1
        buf.append(" ")
        k += 1
        pos = 0
    return None


def registry_match(mix, const, sid):
    for (name, rk, rc, lo, hi) in REGISTRY:
        if rk == mix and rc == const and sid is not None and lo <= sid <= hi:
            return name
    return None


# --- passes -----------------------------------------------------------------
def analyze_file(relpath, text, counts=None):
    findings = []
    counts = {} if counts is None else counts
    code, comment = sanitize(text)
    in_test = test_regions(code)
    if relpath.startswith("tests/"):
        in_test = [True] * len(code)

    is_src = relpath.startswith("src/")

    # pass 1: rng streams
    if is_src and not relpath.startswith("src/rng/"):
        for i, ln in enumerate(code):
            if in_test[i]:
                continue
            m = re.search(r"\bPcg64::new\s*(\()", ln)
            if m:
                if has_allow("rng", code, comment, i):
                    continue
                args = extract_call(code, i, m.start(1))
                mix, const, sid = parse_rng_args(args or "")
                hit = registry_match(mix, const, sid)
                if hit:
                    msg = (
                        f"ad-hoc derivation of declared stream `{hit}` — "
                        f"route through rng::streams"
                    )
                else:
                    msg = (
                        "undeclared RNG stream derivation — declare it in "
                        "rng::streams and route through its constructor"
                    )
                findings.append(("rng-stream", relpath, i + 1, msg))

    # pass 2: unsafe discipline
    allowed = any(relpath == a or relpath.endswith(a) for a in UNSAFE_ALLOWLIST)
    for i, ln in enumerate(code):
        if not word_in("unsafe", ln):
            continue
        if has_allow("unsafe", code, comment, i):
            continue
        if not allowed:
            findings.append((
                "unsafe", relpath, i + 1,
                "`unsafe` outside the kernel-file allowlist",
            ))
            continue
        # need a SAFETY: comment on the line or within 6 lines above
        ok = False
        for j in range(i, max(-1, i - 7), -1):
            if "SAFETY:" in comment[j] or "# Safety" in comment[j]:
                ok = True
                break
            if j < i and code[j].strip() and not code[j].strip().startswith("#["):
                break
        if not ok:
            findings.append((
                "unsafe", relpath, i + 1,
                "`unsafe` without a `// SAFETY:` justification",
            ))

    # pass 3: determinism
    if is_src and any(relpath.startswith(p) for p in DET_MODULES):
        for i, ln in enumerate(code):
            if in_test[i] or has_allow("nondet", code, comment, i):
                continue
            for tok in DET_BANNED:
                if word_in(tok, ln):
                    findings.append((
                        "determinism", relpath, i + 1,
                        f"`{tok}` in a deterministic compute module",
                    ))
                    break
            else:
                if re.search(r"\.(values|keys)\(\)[\w\s().]*\.\s*(sum|fold|product)\b", ln) \
                        or word_in("par_iter", ln):
                    findings.append((
                        "determinism", relpath, i + 1,
                        "unordered reduction in a deterministic compute module",
                    ))

    # pass 4: hot-path allocations
    hot = None
    if any(relpath == h or relpath.endswith(h) for h in HOT_FILES):
        hot = [not t for t in in_test]
    else:
        for suf, names in HOT_FNS.items():
            if relpath == suf or relpath.endswith(suf):
                hot = fn_regions(code, set(names))
                for i, t in enumerate(in_test):
                    if t:
                        hot[i] = False
    if hot:
        for i, ln in enumerate(code):
            if not hot[i]:
                continue
            for tok in ALLOC_TOKENS:
                if tok in ln:
                    if has_allow("alloc", code, comment, i):
                        break
                    findings.append((
                        "hot-alloc", relpath, i + 1,
                        f"`{tok}` in a steady-state function",
                    ))
                    break

    # pass 5: allow-comment audit (counts well-formed waivers per kind,
    # flags malformed attempts — mirrors passes::allow_audit)
    for i, com in enumerate(comment):
        p = com.find("analyze:")
        if p < 0 or not com[p + 8:].lstrip().startswith("allow("):
            continue
        m = re.search(r"analyze:\s*allow\((\w+),([^)]*)\)", com)
        if m and m.group(2).strip():
            kind = m.group(1)
            if kind in ALLOW_KINDS:
                counts[kind] = counts.get(kind, 0) + 1
            else:
                findings.append((
                    "allow-grammar", relpath, i + 1,
                    f"unknown allow kind `{kind}` — expected one of {ALLOW_KINDS}",
                ))
        else:
            findings.append((
                "allow-grammar", relpath, i + 1,
                "malformed allow comment — grammar is "
                "`analyze: allow(<kind>, <reason>)`",
            ))
    return findings


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else "/root/repo/rust"
    all_f = []
    counts = {}
    for base in ("src", "tests"):
        for dirpath, _, files in os.walk(os.path.join(root, base)):
            for f in sorted(files):
                if not f.endswith(".rs"):
                    continue
                p = os.path.join(dirpath, f)
                rel = os.path.relpath(p, root)
                with open(p) as fh:
                    all_f += analyze_file(rel, fh.read(), counts)
    all_f.sort(key=lambda x: (x[1], x[2]))
    for (p, f, l, m) in all_f:
        print(f"{f}:{l}: [{p}] {m}")
    waivers = ", ".join(f"{k}: {v}" for k, v in sorted(counts.items())) or "none"
    print(f"-- {len(all_f)} findings, waivers: {waivers}")
    return 1 if all_f else 0


if __name__ == "__main__":
    sys.exit(main())
