#!/usr/bin/env python3
"""Bench regression gate for the native kernel benches.

Compares a freshly measured ``cargo bench -- --json`` record list against
the checked-in snapshot (``BENCH_native.json`` at the repo root) and fails
when any *headline* case's median slowed down by more than the threshold
(default 25%). Headline cases per group: the ``gemm_scaling`` records the
ISSUE-4 acceptance bar reads off (the ``n512_*`` dense-GEMM matrix and the
``bwd512_*`` kept-column backward matrix), plus the ``dp_scaling``
sparse-reduce data-parallel step (``mlp_r2_sparse``, DESIGN.md §7.6).

Both files may be either a raw record list (what the bench harness writes)
or a snapshot object with a ``records`` key (the repo-root format). An
empty baseline is the bootstrap state: the gate passes with a note, and
the snapshot gets populated by copying a measured CI artifact back in.

Speedups and new cases never fail the gate; a baseline case missing from
the measured set does (a silently dropped bench would otherwise disable
its own gate).

Usage:
  python3 bench_gate.py --measured rust/results/BENCH_native.json \
                        --baseline BENCH_native.json [--threshold 1.25]
"""

import argparse
import json
import sys

# group -> case prefixes gated within it
HEADLINES = {
    "gemm_scaling": ("n512_", "bwd512_"),
    "dp_scaling": ("mlp_r2_sparse",),
}
DEFAULT_THRESHOLD = 1.25


def load_records(path):
    """Record list from either the raw bench dump or the snapshot object."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict):
        data = data.get("records", [])
    if not isinstance(data, list):
        raise SystemExit(f"{path}: expected a record list or a snapshot object")
    return data


def headline_medians(records):
    """{"group/case": median_ms} over the gated headline cases."""
    out = {}
    for r in records:
        case = r.get("case", "")
        prefixes = HEADLINES.get(r.get("group"), ())
        if prefixes and case.startswith(prefixes):
            out[f"{r['group']}/{case}"] = float(r["median_ms"])
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--measured", required=True,
                    help="freshly measured bench JSON (raw record list)")
    ap.add_argument("--baseline", required=True,
                    help="checked-in snapshot to gate against")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="fail when measured > baseline * threshold "
                         f"(default {DEFAULT_THRESHOLD})")
    args = ap.parse_args()

    measured = headline_medians(load_records(args.measured))
    baseline = headline_medians(load_records(args.baseline))

    if not baseline:
        print(f"bench gate: baseline {args.baseline} has no headline "
              f"records yet (bootstrap) — passing; populate it by copying "
              f"a measured CI artifact back into the snapshot")
        return 0
    if not measured:
        print(f"bench gate: measured file {args.measured} has no headline "
              f"records — the benches did not run")
        return 1

    regressions = []
    missing = []
    for case, base_ms in sorted(baseline.items()):
        if case not in measured:
            missing.append(case)
            continue
        got_ms = measured[case]
        ratio = got_ms / base_ms if base_ms > 0 else float("inf")
        marker = "REGRESSED" if ratio > args.threshold else "ok"
        print(f"  {case}: baseline {base_ms:8.3f} ms, "
              f"measured {got_ms:8.3f} ms  ({ratio:5.2f}x) {marker}")
        if ratio > args.threshold:
            regressions.append((case, base_ms, got_ms, ratio))

    if missing:
        print(f"bench gate: {len(missing)} baseline case(s) missing from "
              f"the measured set: {', '.join(missing)}")
        return 1
    if regressions:
        print(f"bench gate: {len(regressions)} headline case(s) slowed "
              f"down by more than {(args.threshold - 1) * 100:.0f}%:")
        for case, base_ms, got_ms, ratio in regressions:
            print(f"  {case}: {base_ms:.3f} ms -> {got_ms:.3f} ms "
                  f"({ratio:.2f}x)")
        return 1
    print(f"bench gate: {len(baseline)} headline case(s) within "
          f"{(args.threshold - 1) * 100:.0f}% of the snapshot")
    return 0


if __name__ == "__main__":
    sys.exit(main())
