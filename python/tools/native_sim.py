"""Faithful Python port of the rust native backend (same RNG streams, same
call order) to pre-verify the deterministic test assertions."""
import numpy as np
import math

MASK128 = (1 << 128) - 1
M64 = (1 << 64) - 1
MUL = 0x2360ed051fc65da44385df649fccf645

class Pcg64:
    def __init__(self, seed, stream):
        seed &= M64; stream &= M64
        inc = (((stream << 1) | 1) ^ 0xda3e39cb94b95bdb) & MASK128
        self.inc = ((inc << 1) | 1) & MASK128
        self.state = 0
        self.state = (self.state * MUL + self.inc) & MASK128
        self.state = (self.state + seed) & MASK128
        self.state = (self.state * MUL + self.inc) & MASK128
    def next_u64(self):
        self.state = (self.state * MUL + self.inc) & MASK128
        rot = self.state >> 122
        xsl = ((self.state >> 64) ^ self.state) & M64
        rot &= 63
        return xsl if rot == 0 else (((xsl >> rot) | (xsl << (64 - rot))) & M64)
    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))
    def f32(self):
        return np.float32(self.f64())
    def below(self, n):
        assert n > 0
        while True:
            x = self.next_u64()
            m = x * n
            l = m & M64
            if l >= ((M64 - n + 1) % n):
                return m >> 64
    def gaussian(self):
        u1 = max(1.0 - self.f64(), 1e-300)
        u2 = self.f64()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)
    def bernoulli(self, p):
        return self.f64() < p
    def shuffle(self, xs):
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]

# ---- data generator (SynthMnist) ----
def mnist_anchors(seed):
    anchors = []
    for cls in range(10):
        rng = Pcg64(seed ^ 0xa17c, 100 + cls)
        coarse = np.zeros(49, np.float32)
        pos = (rng.below(7), rng.below(7))
        for _ in range(12):
            coarse[pos[0] * 7 + pos[1]] = 1.0
            d = rng.below(4)
            if d == 0: pos = (min(pos[0] + 1, 6), pos[1])
            elif d == 1: pos = (max(pos[0] - 1, 0), pos[1])
            elif d == 2: pos = (pos[0], min(pos[1] + 1, 6))
            else: pos = (pos[0], max(pos[1] - 1, 0))
        img = np.zeros(784, np.float32)
        for r in range(28):
            for c in range(28):
                img[r * 28 + c] = coarse[(r // 4) * 7 + (c // 4)]
        anchors.append(img)
    return anchors

def generate(n, seed, split):
    stream = 1 if split == "train" else 2
    rng = Pcg64(seed, stream)
    anchors = mnist_anchors(seed)
    x = np.zeros((n, 784), np.float32)
    y = np.zeros(n, np.int64)
    for i in range(n):
        cls = rng.below(10)
        y[i] = cls
        a = anchors[cls].reshape(28, 28)
        bright = 0.8 + 0.4 * rng.f32()
        dr = rng.below(5) - 2
        dc = rng.below(5) - 2
        row = np.zeros((28, 28), np.float32)
        for r in range(28):
            for c in range(28):
                sr, sc = r - dr, c - dc
                base = a[sr, sc] if 0 <= sr < 28 and 0 <= sc < 28 else 0.0
                noise = np.float32(rng.gaussian()) * np.float32(0.25)
                row[r, c] = min(max(base * bright + noise, -0.5), 1.5)
        x[i] = row.reshape(-1)
    return x, y

# ---- sketch math ----
def pstar_from_weights(w, r):
    n = len(w)
    if r >= n:
        return np.ones(n, np.float32)
    t = [(math.sqrt(max(float(wi), 0.0)), i) for i, wi in enumerate(w)]
    t.sort(key=lambda p: -p[0])
    total_t = sum(v for v, _ in t)
    if total_t <= 0.0:
        return np.full(n, min(max(r / n, 1e-6), 1.0), np.float32)
    suffix = [0.0] * (n + 1)
    for k in range(n - 1, -1, -1):
        suffix[k] = suffix[k + 1] + t[k][0]
    lam = suffix[0] / r
    for k in range(n):
        rem = r - k
        if rem <= 0: break
        cand = suffix[k] / rem
        prev_ok = k == 0 or t[k - 1][0] >= cand - 1e-12
        cur_ok = t[k][0] <= cand + 1e-12
        if prev_ok and cur_ok:
            lam = cand; break
    p = np.zeros(n, np.float32)
    for tv, i in t:
        p[i] = min(max(min(tv / lam, 1.0), 1e-6), 1.0)
    return p

def correlated_bernoulli(rng, p):
    u = max(rng.f64(), 1e-12)
    out = np.zeros(len(p), bool)
    c_prev = 0.0
    for i, pi in enumerate(p):
        c = c_prev + float(pi)
        out[i] = math.floor(c - u) > math.floor(c_prev - u)
        c_prev = c
    return out

def independent_bernoulli(rng, p):
    return np.array([rng.bernoulli(float(pi)) for pi in p])

def column_scores(method, g, w):
    # f64 accumulation over f32 entries, matching rust sketch::column_scores
    abss = np.abs(g.astype(np.float64)).sum(0)
    sq = (g.astype(np.float64) ** 2).sum(0)
    if method in ("l1", "l1_ind"): return (abss * abss).astype(np.float32)
    if method == "ds":
        return ((sq / g.shape[0]) * (w.astype(np.float64) ** 2).sum(1)).astype(np.float32)
    raise ValueError(method)

def sketched_linear_backward(g, x, w, method, budget, rng, need_dx):
    dout = g.shape[1]
    if method == "per_column":
        p = np.full(dout, np.float32(min(max(budget, 1e-6), 1.0)), np.float32)
    else:
        scores = column_scores(method, g, w)
        p = pstar_from_weights(scores, budget * dout)
    independent = method == "per_column" or method.endswith("_ind")
    z = independent_bernoulli(rng, p) if independent else correlated_bernoulli(rng, p)
    inv = np.where(z, 1.0 / p, 0.0).astype(np.float32)
    gh = g * inv[None, :]
    dw = gh.T @ x
    db = gh.sum(0)
    dx = gh @ w if need_dx else None
    return dw, db, dx

# ---- model ----
def mlp_new(dims, seed):
    layers = []
    for li, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        rng = Pcg64(seed ^ 0x1e57, 300 + li)
        std = math.sqrt(2.0 / din)
        wdata = np.array([np.float32(rng.gaussian() * std)
                          for _ in range(dout * din)], np.float32).reshape(dout, din)
        layers.append([wdata, np.zeros(dout, np.float32)])
    return layers

def forward(layers, x):
    acts = [x]; zs = []
    n = len(layers)
    for i, (w, b) in enumerate(layers):
        z = (acts[-1] @ w.T + b).astype(np.float32)
        h = np.maximum(z, 0) if i + 1 < n else z
        zs.append(z); acts.append(h.astype(np.float32))
    return acts, zs

def ce_loss_grad(logits, y):
    m = logits.max(1, keepdims=True)
    e = np.exp((logits - m).astype(np.float32))
    sm = e / e.sum(1, keepdims=True)
    B = len(y)
    loss = -np.log(np.maximum(sm[np.arange(B), y], 1e-12)).mean()
    g = sm.copy(); g[np.arange(B), y] -= 1.0
    return float(loss), (g / B).astype(np.float32)

def backward(layers, acts, zs, dlogits, method, budget, mask, rng):
    n = len(layers)
    dws = [None] * n; dbs = [None] * n
    g = dlogits
    for i in range(n - 1, -1, -1):
        x = acts[i]; w = layers[i][0]
        need_dx = i > 0
        if mask[i] > 0 and method != "baseline":
            dw, db, dx = sketched_linear_backward(g, x, w, method, budget, rng, need_dx)
        else:
            dw = g.T @ x; db = g.sum(0); dx = g @ w if need_dx else None
        dws[i] = dw.astype(np.float32); dbs[i] = db.astype(np.float32)
        if dx is not None:
            dx = dx.astype(np.float32)
            dx[zs[i - 1] <= 0] = 0
            g = dx
    return dws, dbs

def clip(dws, dbs, maxn=1.0):
    # interleaved (w0, b0, w1, b1, ...) f64 sum order, matching the rust
    # Grads::global_norm slot order
    sq = 0.0
    for dw, db in zip(dws, dbs):
        sq += float((dw.astype(np.float64) ** 2).sum())
        sq += float((db.astype(np.float64) ** 2).sum())
    norm = math.sqrt(sq)
    if norm > maxn:
        s = np.float32(maxn / max(norm, 1e-12))
        dws = [d * s for d in dws]; dbs = [d * s for d in dbs]
    return dws, dbs

def run_trainer(dims, method, budget, location, seed, train_size, test_size,
                steps, eval_every, batch, lr):
    xtr, ytr = DATA[("train", train_size)]
    xte, yte = DATA[("test", test_size)]
    layers = mlp_new(dims, seed)
    mask = [0.0] * (len(dims) - 1)
    if location == "all": mask = [1.0] * len(mask)
    sk_rng = Pcg64(seed ^ 0x9e3779b9, 11)
    rng = Pcg64(seed + 77, 3)
    losses = []
    step = 0
    while step < steps:
        order = list(range(train_size))
        rng.shuffle(order)
        cursor = 0
        while cursor + batch <= train_size and step < steps:
            idx = order[cursor:cursor + batch]; cursor += batch
            xb, yb = xtr[idx], ytr[idx]
            acts, zs = forward(layers, xb)
            loss, dl = ce_loss_grad(acts[-1], yb)
            dws, dbs = backward(layers, acts, zs, dl, method, budget, mask, sk_rng)
            dws, dbs = clip(dws, dbs)
            for li in range(len(layers)):
                layers[li][0] = (layers[li][0] - np.float32(lr) * dws[li]).astype(np.float32)
                layers[li][1] = (layers[li][1] - np.float32(lr) * dbs[li]).astype(np.float32)
            losses.append(loss)
            step += 1
    # evaluate
    nb = test_size // batch
    lsum = 0.0; correct = 0.0
    for b in range(nb):
        xb = xte[b * batch:(b + 1) * batch]; yb = yte[b * batch:(b + 1) * batch]
        acts, _ = forward(layers, xb)
        l, _ = ce_loss_grad(acts[-1], yb)
        lsum += l * batch
        correct += (acts[-1].argmax(1) == yb).sum()
    return losses, lsum / (nb * batch), correct / (nb * batch)

DATA = {}
