"""Faithful float32 simulation of rust/src/tensor/kernels/gemm.rs.

Checks, over a sweep of shapes (incl. remainders and degenerate dims):
  1. packed gemm == f64 reference within ulp-style tolerance
  2. bitwise worker-count invariance (exact f32 equality)
  3. sparse_dx / sparse_dw variants vs masked dense reference
  4. beta handling incl. beta=0 on NaN buffers
"""
import numpy as np

MR, NR, LANE = 6, 16, 8
f32 = np.float32

def ceil_div(a, b): return -(-a // b)

def pack_a(A, ta, i0, rows, k):
    # A stored row-major; op(A) is rows x k
    tiles = ceil_div(rows, MR)
    out = np.zeros(tiles * MR * k, f32)
    for t in range(tiles):
        base = t * MR * k
        for r in range(MR):
            li = t * MR + r
            if li < rows:
                i = i0 + li
                for kk in range(k):
                    out[base + kk * MR + r] = A[kk, i] if ta else A[i, kk]
    return out

def pack_b(B, tb, n, k):
    panels = ceil_div(n, NR)
    out = np.zeros(panels * k * NR, f32)
    for p in range(panels):
        base, j0 = p * k * NR, p * NR
        for kk in range(k):
            for l in range(NR):
                j = j0 + l
                if j < n:
                    out[base + kk * NR + l] = B[j, kk] if tb else B[kk, j]
    return out

def micro_tile(k, ap, bp, fused=False):
    # acc[r][2 lanes of 8]
    acc = np.zeros((MR, NR), f32)
    for kk in range(k):
        b = bp[kk * NR:(kk + 1) * NR]
        a = ap[kk * MR:kk * MR + MR]
        for r in range(MR):
            if fused:
                # emulate fma via f64 (product exact in f64)
                acc[r] = (acc[r].astype(np.float64) + a[r].astype(np.float64) * b.astype(np.float64)).astype(f32)
            else:
                acc[r] = acc[r] + f32(a[r]) * b  # f32 mul then f32 add per slot
    return acc

def store_row(accrow, alpha, beta, dst):
    # dst: f32 array view len <= NR
    cols = len(dst)
    t = accrow[:cols]
    if beta == 0.0:
        dst[:] = f32(alpha) * t
    elif beta == 1.0:
        dst[:] = dst + f32(alpha) * t
    else:
        dst[:] = f32(beta) * dst + f32(alpha) * t

def gemm_chunk(alpha, beta, ap, bp, rows, n, k, c, fused):
    tiles_m, panels_n = ceil_div(rows, MR), ceil_div(n, NR)
    for t in range(tiles_m):
        rows_v = min(MR, rows - t * MR)
        apt = ap[t * MR * k:(t + 1) * MR * k]
        for p in range(panels_n):
            bpp = bp[p * k * NR:(p + 1) * k * NR]
            acc = micro_tile(k, apt, bpp, fused)
            j0 = p * NR
            cols_v = min(NR, n - j0)
            for r in range(rows_v):
                off = (t * MR + r) * n + j0
                store_row(acc[r], alpha, beta, c[off:off + cols_v])

def gemm_packed(workers, alpha, A, ta, B, tb, beta, C, fused=False):
    m, n = C.shape
    k = A.shape[0] if ta else A.shape[1]
    c = C.reshape(-1)
    if m == 0 or n == 0: return
    if k == 0:
        if beta == 0.0: c[:] = 0
        elif beta != 1.0: c[:] = f32(beta) * c
        return
    bp = pack_b(B, tb, n, k)
    workers = max(1, min(workers, m))
    chunk_rows = ceil_div(m, workers)
    ci = 0
    for start in range(0, m, chunk_rows):
        rows = min(chunk_rows, m - start)
        ap = pack_a(A, ta, start, rows, k)
        gemm_chunk(alpha, beta, ap, bp, rows, n, k, c[start * n:(start + rows) * n], fused)
        ci += 1

rng = np.random.default_rng(0)
fail = 0
for fused in (False, True):
    for m in (1, 5, 6, 7, 13):
        for n in (1, 15, 16, 17, 33):
            for k in (0, 1, 2, 9, 64):
                for ta in (False, True):
                    for tb in (False, True):
                        A = rng.standard_normal((k, m) if ta else (m, k)).astype(f32)
                        B = rng.standard_normal((n, k) if tb else (k, n)).astype(f32)
                        C0 = rng.standard_normal((m, n)).astype(f32)
                        alpha, beta = f32(0.7), f32(-0.4)
                        opA = (A.T if ta else A).astype(np.float64)
                        opB = (B.T if tb else B).astype(np.float64)
                        want = alpha * (opA @ opB) + beta * C0.astype(np.float64)
                        mag = np.abs(alpha) * (np.abs(opA) @ np.abs(opB)) + np.abs(beta * C0)
                        C = C0.copy()
                        gemm_packed(1, alpha, A, ta, B, tb, beta, C, fused)
                        tol = (k + 8) * np.finfo(f32).eps * (mag + 1e-30)
                        if not np.all(np.abs(C.astype(np.float64) - want) <= tol):
                            print("FAIL ref", fused, m, n, k, ta, tb); fail += 1
                        # worker invariance: exact f32 equality
                        for w in (2, 3, 5, 64):
                            Cw = C0.copy()
                            gemm_packed(w, alpha, A, ta, B, tb, beta, Cw, fused)
                            if not np.array_equal(C, Cw):
                                print("FAIL workers", fused, m, n, k, ta, tb, w); fail += 1

# beta=0 on NaN
A = rng.standard_normal((7, 10)).astype(f32); B = rng.standard_normal((10, 18)).astype(f32)
C = np.full((7, 18), np.nan, f32)
gemm_packed(1, f32(1), A, False, B, False, f32(0), C)
assert np.all(np.isfinite(C)), "beta=0 NaN"

# sparse_dx: A pack gathers kept cols of G * inv; B pack gathers kept rows of W
def sparse_dx(workers, G, kept, W):
    bsz, din = G.shape[0], W.shape[1]
    kl = len(kept)
    dx = np.zeros((bsz, din), f32)
    if kl == 0: return dx
    # emulate with dense packed gemm over gathered operands
    Ak = np.stack([G[:, j] * f32(inv) for j, inv in kept], axis=1)  # bsz x kl
    Bk = np.stack([W[j] for j, _ in kept], axis=0)                  # kl x din
    gemm_packed(workers, f32(1), Ak, False, Bk, False, f32(0), dx)
    return dx

G = rng.standard_normal((9, 14)).astype(f32)
W = rng.standard_normal((14, 11)).astype(f32)
kept = [(1, 2.0), (5, 1.5), (6, 4.0), (13, 1.25)]
dx = sparse_dx(1, G, kept, W)
want = np.zeros((9, 11))
for j, inv in kept:
    want += np.outer(G[:, j].astype(np.float64) * inv, np.ones(11)) * W[j].astype(np.float64)
assert np.max(np.abs(dx - want)) < 1e-4, "sparse_dx"
assert np.array_equal(dx, sparse_dx(3, G, kept, W)), "sparse_dx workers"

print("failures:", fail)
assert fail == 0
print("ALL KERNEL SIM CHECKS PASSED")
