"""Algorithm 1 / Algorithm 2 / estimator properties (paper §3–§4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import sketching

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# Algorithm 1 — waterfilling
# ---------------------------------------------------------------------------
@given(
    n=st.integers(4, 80),
    r_frac=st.floats(0.05, 0.95),
    seed=st.integers(0, 2**31),
)
def test_pstar_budget_and_range(n, r_frac, seed):
    w = jnp.abs(jax.random.normal(jax.random.key(seed), (n,))) + 1e-3
    r = jnp.float32(max(1.0, r_frac * n))
    p = sketching.pstar_from_weights(w, r)
    p = np.asarray(p)
    assert np.all(p > 0) and np.all(p <= 1.0 + 1e-6)
    assert abs(p.sum() - float(r)) < 1e-2 * n


def _bisect_waterfill(w, r):
    """Independent oracle: solve min Σ w/p, Σp=r by bisection on λ."""
    t = np.sqrt(np.maximum(np.asarray(w, np.float64), 0))
    lo, hi = 1e-12, (t.sum() / r) * 10 + 1.0

    def total(lam_sqrt):
        return np.minimum(1.0, t / lam_sqrt).sum()

    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if total(mid) > r:
            lo = mid
        else:
            hi = mid
    return np.minimum(1.0, t / hi)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("r", [2.0, 7.5, 20.0])
def test_pstar_matches_bisection_oracle(seed, r):
    w = jnp.abs(jax.random.normal(jax.random.key(seed), (32,))) + 1e-4
    p = np.asarray(sketching.pstar_from_weights(w, jnp.float32(r)))
    oracle = _bisect_waterfill(w, r)
    assert_allclose(p, oracle, rtol=5e-3, atol=5e-3)


def test_pstar_objective_beats_uniform():
    """Waterfilled probabilities must not lose to uniform p=r/n."""
    w = np.abs(np.random.default_rng(0).normal(size=64)) ** 3 + 1e-6
    r = 12.0
    p = np.asarray(sketching.pstar_from_weights(jnp.asarray(w, jnp.float32), jnp.float32(r)))
    uni = np.full(64, r / 64)
    assert (w / p).sum() <= (w / uni).sum() + 1e-3 * (w / uni).sum()


def test_pstar_saturates_large_budget():
    w = jnp.arange(1.0, 11.0)
    p = np.asarray(sketching.pstar_from_weights(w, jnp.float32(10.0)))
    assert_allclose(p, np.ones(10), atol=1e-6)


def test_pstar_heavy_coordinate_saturates():
    w = jnp.asarray([100.0] + [1e-4] * 15, jnp.float32)
    p = np.asarray(sketching.pstar_from_weights(w, jnp.float32(2.0)))
    assert p[0] == pytest.approx(1.0, abs=1e-5)


# ---------------------------------------------------------------------------
# Algorithm 2 — correlated exact-r sampling
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 2**31), n=st.integers(4, 64), r=st.integers(1, 10))
def test_correlated_sampling_exact_count(seed, n, r):
    r = min(r, n - 1)
    w = jnp.abs(jax.random.normal(jax.random.key(seed), (n,))) + 1e-3
    p = sketching.pstar_from_weights(w, jnp.float32(r))
    z = np.asarray(
        sketching.correlated_bernoulli(jax.random.key(seed + 1), p)
    )
    assert set(np.unique(z)).issubset({0.0, 1.0})
    # Σ z equals the (rounded) total budget a.s.
    assert abs(z.sum() - round(float(np.asarray(p).sum()))) <= 1.0


def test_correlated_sampling_marginals():
    """Empirical selection frequencies match p_i."""
    p = jnp.asarray([0.9, 0.5, 0.25, 0.25, 0.1], jnp.float32)
    trials = 4000
    keys = jax.random.split(jax.random.key(0), trials)
    zs = jax.vmap(lambda k: sketching.correlated_bernoulli(k, p))(keys)
    freq = np.asarray(zs).mean(axis=0)
    assert_allclose(freq, np.asarray(p), atol=0.03)


def test_independent_sampling_marginals():
    p = jnp.asarray([0.8, 0.4, 0.2], jnp.float32)
    keys = jax.random.split(jax.random.key(3), 4000)
    zs = jax.vmap(lambda k: sketching.independent_bernoulli(k, p))(keys)
    assert_allclose(np.asarray(zs).mean(axis=0), np.asarray(p), atol=0.03)


def test_mask_and_rescale_mean_one():
    w = jnp.abs(jax.random.normal(jax.random.key(5), (24,))) + 1e-3
    keys = jax.random.split(jax.random.key(6), 3000)
    ms = jax.vmap(
        lambda k: sketching.mask_and_rescale_vector(k, w, jnp.float32(6.0))
    )(keys)
    mean = np.asarray(ms).mean(axis=0)
    # per-coordinate MC tolerance: 4σ of the z/p estimator over 3000 draws
    p = np.asarray(sketching.pstar_from_weights(w, jnp.float32(6.0)))
    tol = 4.0 * np.sqrt((1.0 / p - 1.0) / 3000) + 1e-3
    assert np.all(np.abs(mean - 1.0) < tol), (mean, tol)


# ---------------------------------------------------------------------------
# Estimator unbiasedness: E[Ĝ-induced dW] = exact dW (Assumption 2.1)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "method",
    ["per_column", "per_sample", "l1", "l2", "var", "ds", "l1_ind", "gsv"],
)
def test_sketch_ghat_unbiased(method):
    b, dout = 16, 12
    g = jax.random.normal(jax.random.key(0), (b, dout))
    w = jax.random.normal(jax.random.key(1), (dout, 8))
    p_budget = jnp.float32(0.4)
    enable = jnp.float32(1.0)

    def one(k):
        ghat, colinv, rowinv = sketching.sketch_ghat(
            method, g, w, k, p_budget, enable
        )
        return ghat * colinv[None, :] * rowinv[:, None]

    n_trials = 1500 if method in ("gsv", "rcs") else 3000
    keys = jax.random.split(jax.random.key(2), n_trials)
    mean = np.asarray(jax.lax.map(one, keys, batch_size=250).mean(axis=0))
    scale = np.abs(np.asarray(g)).mean()
    assert_allclose(mean, np.asarray(g), atol=0.15 * scale + 0.05)


def test_rcs_unbiased_on_vjp_product():
    """RCS is unbiased for what it is optimal for: the VJP product J R g.

    Directions of Γ^{1/2}WWᵀΓ^{1/2} with σᵢ = 0 receive p → floor under
    waterfilling (they cost nothing in distortion because J annihilates
    them), so Ĝ itself is a heavy-tailed estimator whose raw Monte-Carlo
    mean converges impractically slowly in those null directions. The
    downstream product dX = Ĝ W kills exactly those directions — and must
    be cleanly unbiased at MC scale."""
    b, dout = 16, 12
    g = jax.random.normal(jax.random.key(0), (b, dout))
    w = jax.random.normal(jax.random.key(1), (dout, 8))

    def one(k):
        ghat, colinv, rowinv = sketching.sketch_ghat(
            "rcs", g, w, k, jnp.float32(0.4), jnp.float32(1.0)
        )
        return (ghat * colinv[None, :] * rowinv[:, None]) @ w

    n = 4000
    keys = jax.random.split(jax.random.key(2), n)
    samples = jax.lax.map(one, keys, batch_size=250)
    mean = np.asarray(samples.mean(axis=0))
    std = np.asarray(samples.std(axis=0))
    exact = np.asarray(g @ w)
    dev = np.abs(mean - exact)
    bound = 5.0 * std / np.sqrt(n) + 5e-3
    assert np.all(dev < bound), (dev.max(), float(bound.min()))


def test_sketch_ghat_disable_is_exact():
    g = jax.random.normal(jax.random.key(0), (8, 6))
    w = jax.random.normal(jax.random.key(1), (6, 4))
    for method in ["per_column", "l1", "ds", "rcs", "gsv"]:
        ghat, colinv, rowinv = sketching.sketch_ghat(
            method, g, w, jax.random.key(9), jnp.float32(0.3), jnp.float32(0.0)
        )
        full = np.asarray(ghat * colinv[None, :] * rowinv[:, None])
        assert_allclose(full, np.asarray(g), rtol=1e-5, atol=1e-5)


def test_baseline_identity():
    g = jax.random.normal(jax.random.key(0), (8, 6))
    w = jax.random.normal(jax.random.key(1), (6, 4))
    ghat, colinv, rowinv = sketching.sketch_ghat(
        "baseline", g, w, jax.random.key(2), jnp.float32(0.5), jnp.float32(1.0)
    )
    assert_allclose(np.asarray(ghat), np.asarray(g))
    assert np.all(np.asarray(colinv) == 1) and np.all(np.asarray(rowinv) == 1)


# ---------------------------------------------------------------------------
# Lemma 3.1 — optimal unbiased low-rank sketch
# ---------------------------------------------------------------------------
def test_lemma31_unbiased_and_achieves_bound():
    m = jax.random.normal(jax.random.key(0), (12, 10))
    r = jnp.float32(4.0)
    keys = jax.random.split(jax.random.key(1), 3000)
    ss = jax.lax.map(
        lambda k: sketching.optimal_unbiased_sketch(k, m, r)[0], keys,
        batch_size=250,
    )
    mean = np.asarray(ss.mean(axis=0))
    assert_allclose(mean, np.asarray(m), atol=0.12)
    # Monte-Carlo distortion ≈ analytic Σσ²/p − Σσ²
    _, err = sketching.optimal_unbiased_sketch(jax.random.key(2), m, r)
    emp = np.mean(
        [float(jnp.sum((s - m) ** 2)) for s in np.asarray(ss)[:500]]
    )
    assert emp == pytest.approx(float(err), rel=0.2)

    # The lower bound of Lemma 3.1: Σ_{i≤i0}σᵢ² + (Σ_{i>i0}σᵢ)²/(r−i0).
    sv = np.linalg.svd(np.asarray(m), compute_uv=False)
    best = np.inf
    for i0 in range(int(r)):
        best = min(
            best, (sv[:i0] ** 2).sum() + sv[i0:].sum() ** 2 / (float(r) - i0)
        )
    bound = best - (sv**2).sum()
    assert float(err) == pytest.approx(bound, rel=1e-3)


def test_lemma31_beats_uniform_column_sampling():
    """Optimal sketch distortion ≤ uniform coordinate mask distortion."""
    rng = np.random.default_rng(0)
    # strongly anisotropic matrix (low-rank + noise) — where it matters
    m_np = rng.normal(size=(16, 1)) @ rng.normal(size=(1, 16)) * 3
    m_np += rng.normal(size=(16, 16)) * 0.1
    m = jnp.asarray(m_np, jnp.float32)
    r = 4.0
    _, err_opt = sketching.optimal_unbiased_sketch(jax.random.key(0), m, jnp.float32(r))
    # uniform mask-and-rescale distortion: Σ_j ‖m_j‖² (1/p − 1), p = r/n
    p = r / 16.0
    err_uniform = (np.asarray(m) ** 2).sum() * (1 / p - 1)
    assert float(err_opt) < err_uniform
