"""Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes (ragged vs tile-aligned) and block sizes; every
case asserts allclose against kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.scores import column_stats, fused_scores
from compile.kernels.sketch_bwd import sketched_linear_bwd, vmem_bytes
from compile import sketching

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(key, *shape):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32)


dims = st.integers(min_value=1, max_value=70)
blocks = st.sampled_from([8, 16, 32, 128])


@given(b=dims, dout=dims, din=dims, blk=blocks)
def test_sketch_bwd_matches_ref(b, dout, din, blk):
    g = _rand(0, b, dout)
    x = _rand(1, b, din)
    w = _rand(2, dout, din)
    colinv = jnp.abs(_rand(3, dout)) + 0.1
    rowinv = jnp.abs(_rand(4, b)) + 0.1
    dx, dw, db = sketched_linear_bwd(
        g, colinv, rowinv, x, w, block_b=blk, block_dout=blk, block_din=blk
    )
    rdx, rdw, rdb = ref.ref_sketched_linear_bwd(g, colinv, rowinv, x, w)
    assert_allclose(np.asarray(dx), np.asarray(rdx), rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(dw), np.asarray(rdw), rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(db), np.asarray(rdb), rtol=2e-4, atol=2e-4)


@given(b=dims, dout=dims, blk=blocks)
def test_column_stats_matches_ref(b, dout, blk):
    g = _rand(7, b, dout)
    a, s, m = column_stats(g, block_b=blk, block_dout=blk)
    ra, rs, rm = ref.ref_column_stats(g)
    assert_allclose(np.asarray(a), np.asarray(ra), rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(s), np.asarray(rs), rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(m), np.asarray(rm), rtol=2e-4, atol=1e-3)


@pytest.mark.parametrize(
    "method", ["l1", "l1_sq", "l2", "l2_sq", "var", "var_sq", "ds"]
)
def test_fused_scores_match_reference_scores(method):
    g = _rand(11, 37, 29)
    w = _rand(12, 29, 17)
    fused = fused_scores(method, g, w)
    oracle = sketching.column_scores(method, g, w)
    assert_allclose(np.asarray(fused), np.asarray(oracle), rtol=1e-4, atol=1e-5)


def test_masked_columns_are_dead():
    """colinv=0 columns must contribute nothing (block-skip soundness)."""
    g = _rand(21, 16, 24)
    x = _rand(22, 16, 8)
    w = _rand(23, 24, 8)
    colinv = jnp.zeros((24,)).at[3].set(2.0)
    rowinv = jnp.ones((16,))
    dx, dw, db = sketched_linear_bwd(g, colinv, rowinv, x, w)
    gz = jnp.zeros_like(g).at[:, 3].set(g[:, 3] * 2.0)
    assert_allclose(np.asarray(dx), np.asarray(gz @ w), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(dw), np.asarray(gz.T @ x), rtol=1e-5, atol=1e-5)
    assert np.allclose(np.asarray(db), np.asarray(gz.sum(0)), atol=1e-5)


def test_kernel_under_jit_and_grad_free():
    """The kernel must be jittable (it lives inside the AOT train step)."""
    f = jax.jit(lambda g, c, r, x, w: sketched_linear_bwd(g, c, r, x, w))
    g = _rand(31, 32, 16)
    out = f(g, jnp.ones((16,)), jnp.ones((32,)), _rand(32, 32, 8), _rand(33, 16, 8))
    assert out[0].shape == (32, 8)


def test_vmem_estimate_monotone():
    assert vmem_bytes(128, 128, 128) > vmem_bytes(64, 64, 64)
    # default tiling fits a generous VMEM budget (16 MiB/core)
    assert vmem_bytes(128, 128, 128) < 16 * 2**20
