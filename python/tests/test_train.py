"""Train-step builders: optimizers, clipping, loss descent, eval counting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import train


def _run_steps(model, method, steps=15, budget=0.3, lr=0.1, batch=16):
    spec = train.build_train_step(model, method, batch)
    f = jax.jit(spec.fn)
    n = spec.meta["num_params"] + spec.meta["num_opt"]
    args = list(spec.example_inputs)
    key = jax.random.key(7)
    mod_shape = args[n].shape
    args[n] = jax.random.normal(key, mod_shape) * 0.5
    args[n + 1] = jax.random.randint(key, (batch,), 0, 10)
    args[n + 3] = jnp.float32(budget)
    args[n + 5] = jnp.float32(lr)
    losses = []
    for t in range(steps):
        args[n + 2] = jnp.asarray(np.array([t, 3], np.uint32))
        out = f(*args)
        args[:n] = out[:n]
        losses.append(float(out[-1]))
    return losses


@pytest.mark.parametrize("method", ["baseline", "l1", "per_column", "ds"])
def test_mlp_memorizes_fixed_batch(method):
    losses = _run_steps("mlp", method, steps=25)
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0] * 0.8, losses


def test_vit_adamw_steps_are_finite():
    losses = _run_steps("vit", "l1", steps=6, lr=3e-4, batch=8)
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0] + 0.1


def test_bagnet_momentum_steps_are_finite():
    losses = _run_steps("bagnet", "ds", steps=6, lr=0.02, batch=8)
    assert all(np.isfinite(losses)), losses


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0]), "b": jnp.asarray([0.0])}
    clipped = train._clip_by_global_norm(g, 1.0)
    norm = float(
        jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree_util.tree_leaves(clipped)))
    )
    assert norm == pytest.approx(1.0, abs=1e-5)
    # below the threshold: untouched
    small = train._clip_by_global_norm({"a": jnp.asarray([0.1])}, 1.0)
    assert float(small["a"][0]) == pytest.approx(0.1)
    # disabled (clip<=0): untouched
    same = train._clip_by_global_norm(g, 0.0)
    assert float(same["a"][1]) == 4.0


def test_adamw_state_advances():
    cfg = {"kind": "adamw", "b1": 0.9, "b2": 0.999, "wd": 0.0}
    params = {"w": jnp.ones((3,))}
    state = train.opt_init(cfg, params)
    g = {"w": jnp.asarray([1.0, -1.0, 0.5])}
    p1, s1 = train.opt_update(cfg, params, g, state, 0.1)
    assert float(s1["t"]) == 1.0
    # bias-corrected first step ≈ sign-SGD
    assert_allclose(np.asarray(p1["w"]), [0.9, 1.1, 0.9], atol=1e-3)
    p2, s2 = train.opt_update(cfg, p1, g, s1, 0.1)
    assert float(s2["t"]) == 2.0
    assert np.all(np.asarray(p2["w"]) != np.asarray(p1["w"]))


def test_momentum_accumulates():
    cfg = {"kind": "momentum", "mu": 0.9, "wd": 0.0}
    params = {"w": jnp.zeros((1,))}
    state = train.opt_init(cfg, params)
    g = {"w": jnp.asarray([1.0])}
    p, s = train.opt_update(cfg, params, g, state, 1.0)
    assert float(p["w"][0]) == pytest.approx(-1.0)
    p, s = train.opt_update(cfg, p, g, s, 1.0)
    assert float(p["w"][0]) == pytest.approx(-1.0 - 1.9)


def test_weight_decay_applied():
    cfg = {"kind": "momentum", "mu": 0.0, "wd": 0.1}
    params = {"w": jnp.asarray([10.0])}
    state = train.opt_init(cfg, params)
    g = {"w": jnp.asarray([0.0])}
    p, _ = train.opt_update(cfg, params, g, state, 1.0)
    assert float(p["w"][0]) == pytest.approx(9.0)


def test_eval_step_counts():
    spec = train.build_eval_step("mlp", 8)
    f = jax.jit(spec.fn)
    n = spec.meta["num_params"]
    args = list(spec.example_inputs)
    loss_sum, correct = f(*args)
    # zero params, zero inputs → uniform logits → loss = 8·ln10, argmax=0
    assert float(loss_sum) == pytest.approx(8 * np.log(10), rel=1e-3)
    y = np.zeros(8, np.int32)
    args[n + 1] = jnp.asarray(y)
    _, correct = f(*args)
    assert float(correct) == 8.0


def test_cross_entropy_known_value():
    logits = jnp.asarray([[0.0, jnp.log(3.0)]])
    y = jnp.asarray([1])
    # softmax = [1/4, 3/4] → CE = -ln(3/4)
    assert float(train.cross_entropy(logits, y)) == pytest.approx(
        -np.log(0.75), rel=1e-5
    )


def test_grads_builder_dim():
    spec = train.build_grads("mlp", "l1", 8)
    expected = 784 * 64 + 64 + 64 * 64 + 64 + 64 * 10 + 10
    assert spec.meta["grad_dim"] == expected
    out = jax.jit(spec.fn)(*spec.example_inputs)
    assert out[0].shape == (expected,)


def test_tree_names_stable():
    spec = train.build_train_step("mlp", "baseline", 4)
    assert spec.input_names[0].startswith("param.")
    assert spec.input_names[-1] == "lr"
    assert spec.output_names[-1] == "loss"
    # names must be unique (the manifest keys generic rust logic off them)
    assert len(set(spec.input_names)) == len(spec.input_names)
