import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

import jax

jax.config.update("jax_enable_x64", False)
