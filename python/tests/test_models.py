"""Model zoo: shapes, baseline-equals-autodiff, disable-equals-exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import layers, train
from compile.models import REGISTRY, mlp, vit, bagnet


def _inputs(model_name, b=4):
    mod = REGISTRY[model_name]
    k = jax.random.key(0)
    x = jax.random.normal(k, (b,) + mod.INPUT_SHAPE, jnp.float32)
    return mod, x


@pytest.mark.parametrize("model_name", ["mlp", "vit", "bagnet"])
def test_forward_shapes(model_name):
    mod, x = _inputs(model_name)
    params = mod.init(jax.random.key(1))
    lm = jnp.ones((mod.NUM_SKETCHED,), jnp.float32)
    logits = mod.apply(params, x, jax.random.key(2), jnp.float32(0.5), lm, "l1")
    assert logits.shape == (4, mod.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("model_name", ["mlp", "vit", "bagnet"])
def test_sketched_layer_count_matches(model_name):
    """apply() consumes exactly NUM_SKETCHED mask entries: appending extra
    entries must not change the computation (they are never indexed), while
    flipping the *last* real entry must change it."""
    mod, x = _inputs(model_name, b=2)
    params = mod.init(jax.random.key(1))
    k = jax.random.key(2)
    p = jnp.float32(0.3)
    lm = jnp.ones((mod.NUM_SKETCHED,), jnp.float32)
    lm_pad = jnp.concatenate([lm, jnp.zeros((3,), jnp.float32)])

    def grads(mask):
        def loss(pp):
            logits = mod.apply(pp, x, k, p, mask, "per_column")
            return jnp.sum(logits**2)
        return jax.grad(loss)(pp := params)

    g_exact = jax.tree_util.tree_leaves(grads(lm))
    g_pad = jax.tree_util.tree_leaves(grads(lm_pad))
    for a, b in zip(g_exact, g_pad):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    # flipping the last real entry changes the backward
    lm_flip = lm.at[mod.NUM_SKETCHED - 1].set(0.0)
    g_flip = jax.tree_util.tree_leaves(grads(lm_flip))
    assert any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(g_exact, g_flip)
    )


def test_mlp_baseline_grads_equal_autodiff():
    """method='baseline' must be *exactly* reverse-mode AD of the forward."""
    params = mlp.init(jax.random.key(1))
    x = jax.random.normal(jax.random.key(2), (8, 784))
    y = jax.random.randint(jax.random.key(3), (8,), 0, 10)
    lm = jnp.ones((mlp.NUM_SKETCHED,), jnp.float32)

    def loss_sketched(p):
        logits = mlp.apply(p, x, jax.random.key(4), jnp.float32(1.0), lm, "baseline")
        return train.cross_entropy(logits, y)

    def plain_forward(p):
        h = x
        for i in range(3):
            lp = p[f"fc{i}"]
            h = h @ lp["w"].T + lp["b"]
            if i < 2:
                h = jnp.maximum(h, 0.0)
        return train.cross_entropy(h, y)

    g1 = jax.grad(loss_sketched)(params)
    g2 = jax.grad(plain_forward)(params)
    for k in g1:
        assert_allclose(
            np.asarray(g1[k]["w"]), np.asarray(g2[k]["w"]), rtol=1e-4, atol=1e-5
        )


@pytest.mark.parametrize("method", ["per_column", "per_sample", "l1", "ds"])
def test_disabled_layers_give_exact_grads(method):
    """layer_mask = 0 ⇒ any method reduces to exact backward (Fig 4 gate)."""
    params = mlp.init(jax.random.key(1))
    x = jax.random.normal(jax.random.key(2), (8, 784))
    y = jax.random.randint(jax.random.key(3), (8,), 0, 10)
    lm0 = jnp.zeros((mlp.NUM_SKETCHED,), jnp.float32)
    lm1 = jnp.ones((mlp.NUM_SKETCHED,), jnp.float32)

    def loss(p, lm, m):
        logits = mlp.apply(p, x, jax.random.key(4), jnp.float32(0.3), lm, m)
        return train.cross_entropy(logits, y)

    g_dis = jax.grad(loss)(params, lm0, method)
    g_ref = jax.grad(loss)(params, lm1, "baseline")
    for k in g_dis:
        assert_allclose(
            np.asarray(g_dis[k]["w"]), np.asarray(g_ref[k]["w"]),
            rtol=1e-4, atol=1e-5,
        )


def test_vit_token_count():
    assert vit.TOKENS == 16
    x = jax.random.normal(jax.random.key(0), (2, 32, 32, 3))
    toks = layers.patchify(x, vit.PATCH)
    assert toks.shape == (2, 16, 8 * 8 * 3)


def test_patchify_preserves_content():
    x = jnp.arange(2 * 4 * 4 * 1, dtype=jnp.float32).reshape(2, 4, 4, 1)
    t = layers.patchify(x, 2)
    # first patch of first image = pixels (0,0),(0,1),(1,0),(1,1)
    assert_allclose(np.asarray(t[0, 0]), [0, 1, 4, 5])


def test_avgpool():
    x = jnp.asarray([[1.0, 2.0], [3.0, 4.0]]).reshape(1, 2, 2, 1)
    p = layers.avgpool2x2(x)
    assert p.shape == (1, 1, 1, 1)
    assert float(p[0, 0, 0, 0]) == pytest.approx(2.5)


def test_attention_shapes_and_softmax():
    q = jax.random.normal(jax.random.key(0), (2, 5, 8))
    out = layers.attention(q, q, q, n_heads=2)
    assert out.shape == (2, 5, 8)
    # attention over identical tokens = value itself
    ones = jnp.ones((1, 3, 4))
    assert_allclose(
        np.asarray(layers.attention(ones, ones, ones, 2)), np.ones((1, 3, 4))
    )


def test_layernorm_stats():
    x = jax.random.normal(jax.random.key(0), (6, 11)) * 4 + 3
    y = layers.layernorm(x, jnp.ones((11,)), jnp.zeros((11,)))
    assert_allclose(np.asarray(y.mean(-1)), np.zeros(6), atol=1e-5)
    assert_allclose(np.asarray(y.var(-1)), np.ones(6), atol=1e-3)


def test_bagnet_layer_indexing():
    """NUM_SKETCHED must equal the number of sketched calls in apply()."""
    params = bagnet.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3))
    lm = jnp.ones((bagnet.NUM_SKETCHED,), jnp.float32)
    logits = bagnet.apply(params, x, jax.random.key(2), jnp.float32(0.5), lm, "l1")
    assert logits.shape == (2, 10)


def test_key_bits_roundtrip():
    k = jax.random.key(123)
    bits = layers.key_to_bits(k)
    assert bits.dtype == jnp.float32
    k2 = layers.bits_to_key(bits)
    a = jax.random.uniform(k, (3,))
    b = jax.random.uniform(k2, (3,))
    assert_allclose(np.asarray(a), np.asarray(b))
