"""Empirical validation of Proposition 2.2 (variance propagation) and the
paper-level invariant that estimator variance decreases with budget.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import sketching


def _linear_chain_grads(key, g_out, w1, w2, method, p_budget):
    """Two stacked linear VJPs with sketching at both edges.

    Returns ĝ_in = R̂2-sketched VJP of layer2 then R̂1-sketched VJP of layer1,
    mirroring the cascade of Eq. (11) on a 2-layer linear chain.
    """
    k1, k2 = jax.random.split(key)
    ghat2, c2, r2 = sketching.sketch_ghat(
        method, g_out, w2, k2, p_budget, jnp.float32(1.0)
    )
    g_mid = (ghat2 * c2[None, :] * r2[:, None]) @ w2
    ghat1, c1, r1 = sketching.sketch_ghat(
        method, g_mid, w1, k1, p_budget, jnp.float32(1.0)
    )
    return (ghat1 * c1[None, :] * r1[:, None]) @ w1


@pytest.mark.parametrize("method", ["per_column", "l1", "ds"])
def test_cascade_unbiased(method):
    """Prop 2.2 (i): unbiasedness survives the layer cascade."""
    b, d = 8, 10
    g_out = jax.random.normal(jax.random.key(0), (b, d))
    w1 = jax.random.normal(jax.random.key(1), (d, d)) / np.sqrt(d)
    w2 = jax.random.normal(jax.random.key(2), (d, d)) / np.sqrt(d)
    exact = (g_out @ w2) @ w1

    keys = jax.random.split(jax.random.key(3), 4000)
    f = lambda k: _linear_chain_grads(k, g_out, w1, w2, method, jnp.float32(0.4))
    samples = jax.lax.map(f, keys, batch_size=500)
    mean = np.asarray(samples.mean(axis=0))
    scale = np.abs(np.asarray(exact)).mean()
    np.testing.assert_allclose(mean, np.asarray(exact), atol=0.2 * scale + 0.02)


@pytest.mark.parametrize("method", ["per_column", "l1"])
def test_variance_decreases_with_budget(method):
    b, d = 8, 12
    g_out = jax.random.normal(jax.random.key(0), (b, d))
    w1 = jax.random.normal(jax.random.key(1), (d, d)) / np.sqrt(d)
    w2 = jax.random.normal(jax.random.key(2), (d, d)) / np.sqrt(d)
    exact = (g_out @ w2) @ w1

    def var_at(p):
        keys = jax.random.split(jax.random.key(4), 600)
        f = lambda k: _linear_chain_grads(k, g_out, w1, w2, method, jnp.float32(p))
        s = jax.lax.map(f, keys, batch_size=200)
        return float(jnp.mean(jnp.sum((s - exact) ** 2, axis=(1, 2))))

    v_small, v_mid, v_large = var_at(0.15), var_at(0.4), var_at(0.9)
    assert v_small > v_mid > v_large, (v_small, v_mid, v_large)
    assert v_large < 0.25 * v_small


def test_variance_decomposition_two_terms():
    """Prop 2.2 (ii), measured exactly as stated: at a node the error splits
    into a *local* term (Ĵ − J) applied to the NOISY incoming gradient ĝ and
    a *propagated* term J(ĝ − g); the cross-term vanishes by conditional
    unbiasedness, so the variances add. We sample both pieces from the same
    draws and check E‖total‖² = E‖local(ĝ)‖² + E‖prop‖².
    """
    b, d = 6, 10
    g_out = jax.random.normal(jax.random.key(0), (b, d))
    w1 = jax.random.normal(jax.random.key(1), (d, d)) / np.sqrt(d)
    w2 = jax.random.normal(jax.random.key(2), (d, d)) / np.sqrt(d)
    g_mid_exact = g_out @ w2
    p = jnp.float32(0.35)

    def pieces(key):
        k1, k2 = jax.random.split(key)
        ghat2, c2, r2 = sketching.sketch_ghat(
            "per_column", g_out, w2, k2, p, jnp.float32(1.0)
        )
        g_mid_hat = (ghat2 * c2[None, :] * r2[:, None]) @ w2
        ghat1, c1, r1 = sketching.sketch_ghat(
            "per_column", g_mid_hat, w1, k1, p, jnp.float32(1.0)
        )
        masked = ghat1 * c1[None, :] * r1[:, None]
        local = (masked - g_mid_hat) @ w1       # (R̂−I)ĝ then J
        prop = (g_mid_hat - g_mid_exact) @ w1   # J(ĝ − g)
        total = masked @ w1 - g_mid_exact @ w1
        return (
            jnp.sum(local**2),
            jnp.sum(prop**2),
            jnp.sum(total**2),
        )

    keys = jax.random.split(jax.random.key(5), 6000)
    l2, p2, t2 = jax.lax.map(pieces, keys, batch_size=500)
    v_local, v_prop, v_total = float(l2.mean()), float(p2.mean()), float(t2.mean())
    assert v_total == pytest.approx(v_local + v_prop, rel=0.1), (
        v_total,
        v_local,
        v_prop,
    )


def test_error_dampens_with_small_operator_norm():
    """§2.4: small downstream Jacobian norms dampen propagated error."""
    b, d = 6, 10
    g_out = jax.random.normal(jax.random.key(0), (b, d))
    w2 = jax.random.normal(jax.random.key(2), (d, d)) / np.sqrt(d)
    p = jnp.float32(0.3)

    def mid_err_sq(key):
        ghat2, c2, r2 = sketching.sketch_ghat(
            "per_column", g_out, w2, key, p, jnp.float32(1.0)
        )
        return ((ghat2 * c2[None, :] * r2[:, None]) - g_out) @ w2

    keys = jax.random.split(jax.random.key(7), 2000)
    errs = jax.lax.map(mid_err_sq, keys, batch_size=500)
    base = float(jnp.mean(jnp.sum(errs**2, axis=(1, 2))))
    # shrink the Jacobian 10× → propagated variance shrinks 100×
    errs_small = errs * 0.1
    small = float(jnp.mean(jnp.sum(errs_small**2, axis=(1, 2))))
    assert small == pytest.approx(base / 100.0, rel=1e-5)
