"""Jacobi eigensolver substrate vs numpy (python/compile/linalg.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import linalg


def _sym(n, seed, scale=1.0):
    a = np.random.default_rng(seed).normal(size=(n, n)) * scale
    return jnp.asarray((a + a.T) / 2, jnp.float32)


@pytest.mark.parametrize("n", [2, 3, 8, 10, 17, 64])
def test_eigh_matches_numpy(n):
    a = _sym(n, n)
    evals, v = linalg.eigh_jacobi(a)
    ref = np.linalg.eigvalsh(np.asarray(a))[::-1]
    assert_allclose(np.asarray(evals), ref, rtol=2e-4, atol=2e-4)
    # eigenvector property: A v_i = λ_i v_i
    av = np.asarray(a) @ np.asarray(v)
    lv = np.asarray(v) * np.asarray(evals)[None, :]
    assert_allclose(av, lv, rtol=1e-3, atol=2e-3)


@pytest.mark.parametrize("n", [4, 9, 32])
def test_eigenvectors_orthonormal(n):
    a = _sym(n, 100 + n)
    _, v = linalg.eigh_jacobi(a)
    vtv = np.asarray(v).T @ np.asarray(v)
    assert_allclose(vtv, np.eye(n), atol=2e-4)


def test_reconstruction():
    a = _sym(12, 7)
    evals, v = linalg.eigh_jacobi(a)
    recon = (np.asarray(v) * np.asarray(evals)[None, :]) @ np.asarray(v).T
    assert_allclose(recon, np.asarray(a), atol=2e-4)


def test_psd_gram_eigs_nonnegative():
    g = jax.random.normal(jax.random.key(0), (20, 8))
    gram = g.T @ g
    evals, _ = linalg.eigh_jacobi(gram)
    assert np.asarray(evals).min() > -1e-3


def test_eigh_inside_jit():
    """Must trace/lower (it lives inside the RCS train-step artifact)."""
    f = jax.jit(lambda a: linalg.eigh_jacobi(a)[0])
    a = _sym(6, 3)
    evals = f(a)
    ref = np.linalg.eigvalsh(np.asarray(a))[::-1]
    assert_allclose(np.asarray(evals), ref, rtol=1e-3, atol=1e-3)


def test_singular_values_gram():
    m = jax.random.normal(jax.random.key(1), (15, 6))
    sv = linalg.singular_values_gram(m)
    ref = np.linalg.svd(np.asarray(m), compute_uv=False)
    assert_allclose(np.asarray(sv), ref, rtol=1e-3, atol=1e-3)


def test_degenerate_eigenvalues():
    # repeated eigenvalues (identity block) must not break convergence
    a = jnp.diag(jnp.asarray([3.0, 3.0, 3.0, 1.0], jnp.float32))
    evals, v = linalg.eigh_jacobi(a)
    assert_allclose(np.asarray(evals), [3, 3, 3, 1], atol=1e-5)
    assert_allclose(np.asarray(v).T @ np.asarray(v), np.eye(4), atol=1e-5)
