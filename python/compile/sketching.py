"""Unbiased randomized VJP sketching — core algorithms (paper §3–§4).

Everything in this module is pure-jnp and jit/AOT-friendly: the sketch
*method* is a static (trace-time) choice, while the budget ``p`` (fraction of
kept coordinates), the per-layer ``enable`` gate and all PRNG keys are traced
runtime inputs, so a single lowered artifact serves every budget / layer
placement / seed.

Implemented estimators (names follow the paper):

uniform masks (§4.1)
    ``per_element``   Bernoulli(p) mask on every entry of W and X (Alg. 3)
    ``per_column``    i.i.d. Bernoulli(p) gate per output column (Alg. 5)
    ``per_sample``    one Bernoulli(p) gate per batch row (Alg. 4)

data-dependent coordinate sketches (§4.2, solved via Alg. 1 + Alg. 2)
    ``l1``     weights w_j = ‖G[:,j]‖₁²           → p_j ∝ ‖G[:,j]‖₁
    ``l1_sq``  weights w_j = ‖G[:,j]‖₁⁴           → p_j ∝ ‖G[:,j]‖₁²
    ``l2``     weights w_j = ‖G[:,j]‖₂²           → p_j ∝ ‖G[:,j]‖₂
    ``l2_sq``  weights w_j = ‖G[:,j]‖₂⁴           → p_j ∝ ‖G[:,j]‖₂²
    ``var``    weights w_j = Var_b(G[:,j])        → p_j ∝ sqrt(Var)
    ``var_sq`` weights w_j = Var²                 → p_j ∝ Var
    ``ds``     Lemma 3.4 optimum: w_j = (Γ_B)_jj (JᵀJ)_jj
    ``l1_ind`` ℓ1 scores + *independent* Bernoulli sampling (Fig 1a ablation)

spectral sketches (§4.2)
    ``gsv``    eigenbasis of GᵀG (left singular basis of the gradient
               matrix), weights = eigenvalues          → p_i ∝ σ_i
    ``gsv_sq`` same basis, weights = eigenvalues²      → p_i ∝ σ_i²
    ``rcs``    Prop 3.3 optimum: eigenbasis of Γ^{1/2} JᵀJ Γ^{1/2},
               R* = Γ^{1/2} U diag(z/p*) Uᵀ Γ^{-1/2}

Conventions: row-major batches (Appendix C.1) — activations X ∈ R^{B×d_in},
output gradients G ∈ R^{B×d_out}, weights W ∈ R^{d_out×d_in}; the Jacobian of
the input-VJP is Wᵀ so (JᵀJ) restricted to masked coordinates is WWᵀ and its
diagonal is the squared row norms of W.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import linalg

# All sketch method names, grouped.
UNIFORM_METHODS = ("per_element", "per_column", "per_sample")
COORD_METHODS = ("l1", "l1_sq", "l2", "l2_sq", "var", "var_sq", "ds", "l1_ind")
SPECTRAL_METHODS = ("gsv", "gsv_sq", "rcs")
ALL_METHODS = ("baseline",) + UNIFORM_METHODS + COORD_METHODS + SPECTRAL_METHODS

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Algorithm 1 — waterfilling solution of  min Σ w_i / p_i  s.t.  Σ p_i ≤ r
# ---------------------------------------------------------------------------
def pstar_from_weights(w: jax.Array, r: jax.Array) -> jax.Array:
    """Optimal sampling probabilities for importance weights ``w`` (Alg. 1).

    Solves the convex program (23): minimize Σ w_i/p_i subject to Σ p_i = r,
    0 < p_i ≤ 1. The KKT conditions give the thresholding structure
    p_i* = min(1, sqrt(w_i)/sqrt(λ)) with λ chosen so the budget is met.

    Fully traced: ``r`` may be a scalar array (r = p·n at call sites). Zero
    weights receive a floor probability so the estimator stays well-defined
    (1/p_i never divides by zero); the floor is far below any kept mass.
    """
    n = w.shape[0]
    t = jnp.sqrt(jnp.maximum(w, 0.0))
    order = jnp.argsort(-t)
    ts = t[order]
    # suffix[k] = sum_{i >= k} ts[i]
    suffix = jnp.cumsum(ts[::-1])[::-1]
    ks = jnp.arange(n, dtype=w.dtype)
    denom = jnp.maximum(r - ks, _EPS)
    lam_sqrt = suffix / denom  # candidate threshold with k entries saturated
    # k is valid when the k saturated entries are ≥ threshold and the rest ≤.
    prev_ok = jnp.concatenate(
        [jnp.ones((1,), bool), ts[:-1] >= lam_sqrt[1:] - 1e-9]
    )
    ok = prev_ok & (ts <= lam_sqrt + 1e-9) & (r - ks > 0)
    k_idx = jnp.argmax(ok)
    lam = jnp.maximum(lam_sqrt[k_idx], _EPS)
    p_sorted = jnp.minimum(1.0, ts / lam)
    p_sorted = jnp.where(jnp.arange(n) < k_idx, 1.0, p_sorted)
    p = jnp.zeros_like(p_sorted).at[order].set(p_sorted)
    # Budget ≥ n (or degenerate weights): keep everything.
    p = jnp.where((r >= n) | (jnp.sum(t) <= _EPS), jnp.ones_like(p), p)
    return jnp.clip(p, 1e-6, 1.0)


# ---------------------------------------------------------------------------
# Algorithm 2 — correlated exact-r sampling (systematic sampling)
# ---------------------------------------------------------------------------
def correlated_bernoulli(key: jax.Array, p: jax.Array) -> jax.Array:
    """Sample Z_i ~ Bernoulli(p_i) with Σ Z_i = ⌈Σ p_i⌉ or ⌊Σ p_i⌋ a.s.

    Systematic sampling: draw u ~ U(0,1) and select index i iff some point
    u + ℓ (ℓ ∈ N) falls in the cumulative interval (C_{i-1}, C_i]. Marginals
    are exactly p_i (p_i ≤ 1) and the sample size is fixed given Σ p_i — the
    correlated scheme of Lemma 3.1 / Alg. 2, fully vectorized.
    """
    c = jnp.cumsum(p)
    prev = c - p
    u = jax.random.uniform(key, (), dtype=p.dtype)
    z = jnp.floor(c - u) - jnp.floor(prev - u)
    return jnp.clip(z, 0.0, 1.0)


def independent_bernoulli(key: jax.Array, p: jax.Array) -> jax.Array:
    """Independent Bernoulli(p_i) gates (Lemma 3.4 sampling model)."""
    return (jax.random.uniform(key, p.shape, dtype=p.dtype) < p).astype(p.dtype)


def mask_and_rescale_vector(
    key: jax.Array, w: jax.Array, r: jax.Array, correlated: bool = True
) -> jax.Array:
    """End-to-end coordinate gate: weights → p* → z → z/p* (mean-one)."""
    p = pstar_from_weights(w, r)
    z = correlated_bernoulli(key, p) if correlated else independent_bernoulli(key, p)
    return z / p


# ---------------------------------------------------------------------------
# Column scores (§4.2 proxies)
# ---------------------------------------------------------------------------
def column_scores(method: str, g: jax.Array, w_mat: jax.Array) -> jax.Array:
    """Importance weights w_j per output column for coordinate methods.

    ``g`` is the (B, d_out) output gradient, ``w_mat`` the (d_out, d_in)
    weight matrix (only used by ``ds``).
    """
    if method in ("l1", "l1_ind"):
        s = jnp.sum(jnp.abs(g), axis=0)
        return s * s
    if method == "l1_sq":
        s = jnp.sum(jnp.abs(g), axis=0)
        return (s * s) ** 2
    if method == "l2":
        return jnp.sum(g * g, axis=0)
    if method == "l2_sq":
        return jnp.sum(g * g, axis=0) ** 2
    if method == "var":
        return jnp.var(g, axis=0)
    if method == "var_sq":
        return jnp.var(g, axis=0) ** 2
    if method == "ds":
        gamma_diag = jnp.mean(g * g, axis=0)  # (Γ_B)_jj
        jtj_diag = jnp.sum(w_mat * w_mat, axis=1)  # (WWᵀ)_jj
        return gamma_diag * jtj_diag
    raise ValueError(f"unknown coordinate method {method!r}")


# ---------------------------------------------------------------------------
# Sketch application: produce Ĝ (the masked / sketched output gradient)
# ---------------------------------------------------------------------------
def _blend(minv: jax.Array, enable: jax.Array) -> jax.Array:
    """Per-layer gating: enable=1 → sketched, enable=0 → exact (all-ones)."""
    return enable * minv + (1.0 - enable) * jnp.ones_like(minv)


def sketch_ghat(
    method: str,
    g: jax.Array,
    w_mat: jax.Array,
    key: jax.Array,
    p_budget: jax.Array,
    enable: jax.Array,
):
    """Return (ghat, colinv, rowinv) for the sketched backward pass.

    For coordinate/uniform methods the sketch factors as element-wise scaling
    ``ghat = g * rowinv[:, None] * colinv[None, :]`` and we return the two
    mean-one vectors so the Pallas kernel can fuse the scaling into its tile
    loads. For spectral methods (gsv/rcs) the sketch is a dense basis change
    and ``ghat`` is returned with colinv/rowinv = ones.
    """
    b, dout = g.shape
    dtype = g.dtype
    ones_col = jnp.ones((dout,), dtype)
    ones_row = jnp.ones((b,), dtype)

    if method == "baseline":
        return g, ones_col, ones_row

    if method == "per_column":
        z = independent_bernoulli(key, jnp.full((dout,), p_budget, dtype))
        colinv = _blend(z / p_budget, enable)
        return g, colinv, ones_row

    if method == "per_sample":
        z = independent_bernoulli(key, jnp.full((b,), p_budget, dtype))
        rowinv = _blend(z / p_budget, enable)
        return g, ones_col, rowinv

    if method in COORD_METHODS:
        scores = column_scores("l1" if method == "l1_ind" else method, g, w_mat)
        r = p_budget * dout
        p = pstar_from_weights(scores, r)
        z = (
            independent_bernoulli(key, p)
            if method == "l1_ind"
            else correlated_bernoulli(key, p)
        )
        colinv = _blend(z / p, enable)
        return g, colinv, ones_row

    if method in ("gsv", "gsv_sq"):
        ghat = _gsv_sketch(g, key, p_budget, squared=method == "gsv_sq")
        ghat = enable * ghat + (1.0 - enable) * g
        return ghat, ones_col, ones_row

    if method == "rcs":
        ghat = _rcs_sketch(g, w_mat, key, p_budget)
        ghat = enable * ghat + (1.0 - enable) * g
        return ghat, ones_col, ones_row

    raise ValueError(f"unknown sketch method {method!r}")


def _gsv_sketch(g, key, p_budget, squared=False):
    """G-SV sketch: gate in the left singular basis of the gradient matrix.

    Eigendecompose GᵀG (row convention: (d_out, d_out) Gram of columns) with
    the pure-jnp parallel Jacobi solver, allocate the budget over eigen-
    directions by eigenvalue (squared singular values), and rescale kept
    directions by 1/p — an unbiased R = U diag(z/p) Uᵀ with E[R] = I.
    """
    dout = g.shape[1]
    gram = g.T @ g / g.shape[0]
    evals, u = linalg.eigh_jacobi(gram)
    w = jnp.maximum(evals, 0.0)
    if squared:
        w = w * w
    r = p_budget * dout
    p = pstar_from_weights(w, r)
    z = correlated_bernoulli(key, p)
    diag = z / p
    # ghat rows: R g = U diag Uᵀ g  → row convention: ghat = g (U diag Uᵀ)ᵀ
    return (g @ u) * diag[None, :] @ u.T


def _rcs_sketch(g, w_mat, key, p_budget, ridge=1e-6):
    """Rank-Constraint Sketch (Prop 3.3): the minimal-distortion unbiased R.

    R* = Γ^{1/2} U diag(z_i/p_i*) Uᵀ Γ^{-1/2} with U, σ² the eigensystem of
    Γ^{1/2} (WWᵀ) Γ^{1/2} and p* waterfilled over σ². Γ^{±1/2} come from the
    same Jacobi eigensolver (pure matmuls — no LAPACK custom-calls, see
    DESIGN.md §Hardware-Adaptation). Γ is ridge-regularized: the batch Gram
    is rank ≤ B and Γ^{-1/2} must exist.
    """
    dout = g.shape[1]
    gamma = g.T @ g / g.shape[0] + ridge * jnp.eye(dout, dtype=g.dtype)
    gevals, q = linalg.eigh_jacobi(gamma)
    gevals = jnp.maximum(gevals, ridge)
    ghalf = (q * jnp.sqrt(gevals)[None, :]) @ q.T
    ginvhalf = (q * (1.0 / jnp.sqrt(gevals))[None, :]) @ q.T
    jtj = w_mat @ w_mat.T  # (d_out, d_out) = WWᵀ
    k = ghalf @ jtj @ ghalf
    sig2, u = linalg.eigh_jacobi(k)
    r = p_budget * dout
    p = pstar_from_weights(jnp.maximum(sig2, 0.0), r)
    z = correlated_bernoulli(key, p)
    diag = z / p
    # R = Γ^{1/2} U diag Uᵀ Γ^{-1/2}; rows transform by Rᵀ.
    r_t = ginvhalf @ (u * diag[None, :]) @ u.T @ ghalf
    return g @ r_t


# ---------------------------------------------------------------------------
# Optimal unbiased low-rank sketch of a fixed matrix (Lemma 3.1) — used by
# the lemma31 validation experiment and pytest, not on the training path.
# ---------------------------------------------------------------------------
def optimal_unbiased_sketch(key: jax.Array, m: jax.Array, r: jax.Array):
    """Sample the Lemma 3.1 minimal-distortion unbiased rank-r sketch of M.

    Returns (S, expected_frobenius_sq_error). Uses the Jacobi eigensolver on
    MᵀM / MMᵀ to stay LAPACK-free.
    """
    mm = m.T @ m if m.shape[0] >= m.shape[1] else m @ m.T
    evals, v = linalg.eigh_jacobi(mm)
    sig = jnp.sqrt(jnp.maximum(evals, 0.0))
    p = pstar_from_weights(jnp.maximum(evals, 0.0), r)
    z = correlated_bernoulli(key, p)
    diag = z / p
    if m.shape[0] >= m.shape[1]:
        s = m @ (v * diag[None, :]) @ v.T  # scale right singular directions
    else:
        s = v @ (v.T * diag[:, None]) @ m
    err = jnp.sum(sig**2 / p) - jnp.sum(sig**2)
    return s, err
