"""Model zoo: the paper's three experimental architectures (§5)."""

from . import mlp, vit, bagnet

REGISTRY = {
    "mlp": mlp,
    "vit": vit,
    "bagnet": bagnet,
}
