"""Visual Transformer of §5/Appendix B.2, scaled to this testbed.

Paper config: embed 192, MLP 1024, depth 9, 12 heads, patch 4 on CIFAR-10.
Ours (DESIGN.md §6): embed 64, MLP 256, depth 3, 4 heads, patch 8 — all
structural elements preserved (attention blocks + feed-forward linears, both
sketched; classification head exact, as in the paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers

EMBED = 64
MLP_DIM = 256
DEPTH = 3
HEADS = 4
PATCH = 8
IMG = 32
CHANNELS = 3
TOKENS = (IMG // PATCH) ** 2
INPUT_SHAPE = (IMG, IMG, CHANNELS)
NUM_CLASSES = 10
# sketched layers: patch embed + per block (q, k, v, o, mlp1, mlp2)
NUM_SKETCHED = 1 + DEPTH * 6


def _dense_init(key, dout, din, scale=None):
    scale = scale if scale is not None else jnp.sqrt(2.0 / din)
    return {
        "w": jax.random.normal(key, (dout, din), jnp.float32) * scale,
        "b": jnp.zeros((dout,), jnp.float32),
    }


def init(key: jax.Array):
    keys = iter(jax.random.split(key, 64))
    patch_dim = PATCH * PATCH * CHANNELS
    params = {
        "embed": _dense_init(next(keys), EMBED, patch_dim),
        "pos": jax.random.normal(next(keys), (TOKENS, EMBED), jnp.float32) * 0.02,
        "head": _dense_init(next(keys), NUM_CLASSES, EMBED, scale=0.01),
        "ln_f": {"g": jnp.ones((EMBED,)), "b": jnp.zeros((EMBED,))},
    }
    for d in range(DEPTH):
        blk = {
            "ln1": {"g": jnp.ones((EMBED,)), "b": jnp.zeros((EMBED,))},
            "ln2": {"g": jnp.ones((EMBED,)), "b": jnp.zeros((EMBED,))},
            "q": _dense_init(next(keys), EMBED, EMBED, scale=EMBED**-0.5),
            "k": _dense_init(next(keys), EMBED, EMBED, scale=EMBED**-0.5),
            "v": _dense_init(next(keys), EMBED, EMBED, scale=EMBED**-0.5),
            "o": _dense_init(next(keys), EMBED, EMBED, scale=EMBED**-0.5),
            "mlp1": _dense_init(next(keys), MLP_DIM, EMBED),
            "mlp2": _dense_init(next(keys), EMBED, MLP_DIM),
        }
        params[f"block{d}"] = blk
    return params


def apply(params, x, key, p_budget, layer_mask, method: str):
    """x: (B, 32, 32, 3) images → (B, 10) logits."""

    li = [0]  # running sketched-layer index

    def slin(p, h, lm_key):
        i = li[0]
        li[0] += 1
        lkey = jax.random.fold_in(lm_key, i)
        return layers.sketched_linear(
            method, h, p["w"], p["b"], lkey, p_budget, layer_mask[i]
        )

    tokens = layers.patchify(x, PATCH)
    h = slin(params["embed"], tokens, key) + params["pos"][None, :, :]
    for d in range(DEPTH):
        blk = params[f"block{d}"]
        hn = layers.layernorm(h, blk["ln1"]["g"], blk["ln1"]["b"])
        q = slin(blk["q"], hn, key)
        k = slin(blk["k"], hn, key)
        v = slin(blk["v"], hn, key)
        att = layers.attention(q, k, v, HEADS)
        h = h + slin(blk["o"], att, key)
        hn = layers.layernorm(h, blk["ln2"]["g"], blk["ln2"]["b"])
        m = layers.gelu(slin(blk["mlp1"], hn, key))
        h = h + slin(blk["mlp2"], m, key)
    h = layers.layernorm(h, params["ln_f"]["g"], params["ln_f"]["b"])
    pooled = jnp.mean(h, axis=1)
    # classification head: exact backward (excluded from sketching, §5)
    return pooled @ params["head"]["w"].T + params["head"]["b"]
