"""BagNet-style residual network of §5, scaled to this testbed.

Note: real BagNet-17 uses BatchNorm; running statistics complicate the AOT
step interface (state that is neither a parameter nor an optimizer slot), so
we substitute channel LayerNorm — same conditioning role, stateless
(DESIGN.md §6). Without normalization the sketched 1×1-conv backward (whose
rescaled-mask variance is large at small p) destabilizes momentum training.

BagNet (Brendel & Bethge 2019) is ResNet-like but built almost entirely from
1×1 convolutions — which the paper "assimilates as linear layers and
sketches". We keep exactly that structure: a single exact 3×3 stem (the
paper excludes the initial input projection), then stages of residual blocks
whose 1×1 convs are sketched linears applied over the channel axis with the
pixel grid folded into the batch. Classifier head exact (excluded, §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .. import layers

STAGE_WIDTHS = (16, 32, 64)
BLOCKS_PER_STAGE = 2
IMG = 32
CHANNELS = 3
INPUT_SHAPE = (IMG, IMG, CHANNELS)
NUM_CLASSES = 10
# per block: two 1×1 convs; per stage transition (incl. stem→stage0): one 1×1
NUM_SKETCHED = len(STAGE_WIDTHS) * BLOCKS_PER_STAGE * 2 + len(STAGE_WIDTHS)


def _dense_init(key, dout, din):
    return {
        "w": jax.random.normal(key, (dout, din), jnp.float32)
        * jnp.sqrt(2.0 / din),
        "b": jnp.zeros((dout,), jnp.float32),
    }


def _ln_init(width):
    return {"g": jnp.ones((width,)), "b": jnp.zeros((width,))}


def init(key: jax.Array):
    keys = iter(jax.random.split(key, 64))
    params = {
        "stem": {
            "w": jax.random.normal(next(keys), (3, 3, CHANNELS, STAGE_WIDTHS[0]))
            * jnp.sqrt(2.0 / (9 * CHANNELS)),
            "b": jnp.zeros((STAGE_WIDTHS[0],)),
        },
        "head": _dense_init(next(keys), NUM_CLASSES, STAGE_WIDTHS[-1]),
    }
    cin = STAGE_WIDTHS[0]
    for s, width in enumerate(STAGE_WIDTHS):
        params[f"trans{s}"] = _dense_init(next(keys), width, cin)
        params[f"trans{s}_ln"] = _ln_init(width)
        for b in range(BLOCKS_PER_STAGE):
            params[f"s{s}b{b}"] = {
                "c1": _dense_init(next(keys), width, width),
                "ln1": _ln_init(width),
                "c2": _dense_init(next(keys), width, width),
                "ln2": _ln_init(width),
            }
        cin = width
    return params


def apply(params, x, key, p_budget, layer_mask, method: str):
    """x: (B, 32, 32, 3) images → (B, 10) logits."""
    li = [0]

    def slin(p, h):
        i = li[0]
        li[0] += 1
        lkey = jax.random.fold_in(key, i)
        return layers.sketched_linear(
            method, h, p["w"], p["b"], lkey, p_budget, layer_mask[i]
        )

    # exact 3×3 stem (NHWC), stride 2: pixels fold into the sketch batch
    # downstream, so the stem halves resolution up front (testbed scaling,
    # DESIGN.md §6 — structure preserved, 4× fewer folded rows).
    h = lax.conv_general_dilated(
        x,
        params["stem"]["w"],
        window_strides=(2, 2),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + params["stem"]["b"]
    h = layers.relu(h)

    for s in range(len(STAGE_WIDTHS)):
        if s > 0:
            h = layers.avgpool2x2(h)
        h = slin(params[f"trans{s}"], h)  # 1×1 channel projection
        ln = params[f"trans{s}_ln"]
        h = layers.layernorm(h, ln["g"], ln["b"])
        for b in range(BLOCKS_PER_STAGE):
            blk = params[f"s{s}b{b}"]
            r = slin(blk["c1"], h)
            r = layers.relu(layers.layernorm(r, blk["ln1"]["g"], blk["ln1"]["b"]))
            r = slin(blk["c2"], r)
            r = layers.layernorm(r, blk["ln2"]["g"], blk["ln2"]["b"])
            h = layers.relu(h + r)

    pooled = jnp.mean(h, axis=(1, 2))
    return pooled @ params["head"]["w"].T + params["head"]["b"]
