"""4-layer MLP of §5 (784 → 64 → 64 → 10), all linear layers sketched.

"We train 4-layer MLPs on MNIST: input dimension 784, two hidden layers of
width 64, and a 10-way output." Every linear layer's VJP is replaced by the
chosen estimator (the paper approximates at all layers except the baseline).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers

DIMS = (784, 64, 64, 10)
NUM_SKETCHED = len(DIMS) - 1  # 3 linear layers
INPUT_SHAPE = (784,)
NUM_CLASSES = 10


def init(key: jax.Array):
    """He-initialized parameters as a pytree (dict of per-layer dicts)."""
    params = {}
    for i, (din, dout) in enumerate(zip(DIMS[:-1], DIMS[1:])):
        key, sub = jax.random.split(key)
        params[f"fc{i}"] = {
            "w": jax.random.normal(sub, (dout, din), jnp.float32)
            * jnp.sqrt(2.0 / din),
            "b": jnp.zeros((dout,), jnp.float32),
        }
    return params


def apply(params, x, key, p_budget, layer_mask, method: str):
    """Forward pass; backward uses the ``method`` estimator per layer."""
    h = x
    n = len(DIMS) - 1
    for i in range(n):
        lkey = jax.random.fold_in(key, i)
        lp = params[f"fc{i}"]
        h = layers.sketched_linear(
            method, h, lp["w"], lp["b"], lkey, p_budget, layer_mask[i]
        )
        if i < n - 1:
            h = layers.relu(h)
    return h
