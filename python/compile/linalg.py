"""Pure-jnp dense linear algebra substrate.

Why this exists: ``jnp.linalg.eigh`` lowers to a LAPACK ``custom-call`` that
the pinned PJRT runtime (xla_extension 0.5.1 CPU) cannot execute, and real-TPU
lowering would emit a Mosaic call. The spectral sketches of the paper (G-SV,
RCS — Prop 3.3) need full symmetric eigendecompositions *inside* the AOT-
compiled train step, so we implement a **parallel-ordered cyclic Jacobi**
eigensolver out of plain matmuls + scatters. On TPU this maps cleanly onto the
MXU (each round is two n×n matmuls); on the CPU PJRT runtime it executes as
ordinary HLO.

The pair schedule is the classic round-robin tournament: n−1 rounds of n/2
disjoint pivots, each round applied as one orthogonal similarity transform.
Jacobi converges quadratically once sweeps start; ``sweeps=10`` reaches ~1e-6
relative accuracy for the matrix sizes used here (n ≤ 256), validated against
numpy in ``python/tests/test_linalg.py``.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax


def _round_robin_pairs(n: int) -> np.ndarray:
    """Static (n-1, n/2, 2) round-robin pairing of n players (n even)."""
    assert n % 2 == 0
    arr = list(range(n))
    rounds = []
    for _ in range(n - 1):
        rounds.append([(arr[i], arr[n - 1 - i]) for i in range(n // 2)])
        arr = [arr[0]] + [arr[-1]] + arr[1:-1]
    return np.asarray(rounds, dtype=np.int32)


def eigh_jacobi(a: jax.Array, sweeps: int = 10):
    """Symmetric eigendecomposition A = V diag(w) Vᵀ via parallel Jacobi.

    Returns eigenvalues in descending order and the matching eigenvectors as
    columns of V. ``a`` must be symmetric; only the symmetric part is used.
    """
    n = a.shape[0]
    a = 0.5 * (a + a.T)
    padded = n % 2 == 1
    if padded:
        # Decouple the padding index with a zero row/col; drop it at the end.
        a = jnp.pad(a, ((0, 1), (0, 1)))
    m = a.shape[0]
    pairs = jnp.asarray(_round_robin_pairs(m))
    n_rounds = pairs.shape[0]
    eye = jnp.eye(m, dtype=a.dtype)

    def round_body(r, carry):
        amat, v = carry
        pq = pairs[r % n_rounds]
        ps, qs = pq[:, 0], pq[:, 1]
        app = amat[ps, ps]
        aqq = amat[qs, qs]
        apq = amat[ps, qs]
        small = jnp.abs(apq) < 1e-30
        safe_apq = jnp.where(small, 1.0, apq)
        tau = (aqq - app) / (2.0 * safe_apq)
        t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
        t = jnp.where(tau == 0.0, 1.0, t)  # 45° rotation when diag equal
        c = 1.0 / jnp.sqrt(1.0 + t * t)
        s = t * c
        c = jnp.where(small, 1.0, c)
        s = jnp.where(small, 0.0, s)
        # One orthogonal transform for the whole round (disjoint pivots).
        rot = eye.at[ps, ps].set(c).at[qs, qs].set(c)
        rot = rot.at[ps, qs].set(s).at[qs, ps].set(-s)
        amat = rot.T @ amat @ rot
        amat = 0.5 * (amat + amat.T)  # kill rounding drift off symmetry
        v = v @ rot
        return amat, v

    amat, v = lax.fori_loop(0, sweeps * n_rounds, round_body, (a, eye))
    evals = jnp.diagonal(amat)
    if padded:
        evals = evals[:n]
        v = v[:n, :n]
    order = jnp.argsort(-evals)
    return evals[order], v[:, order]


def singular_values_gram(m: jax.Array, sweeps: int = 10) -> jax.Array:
    """Singular values of M via the (smaller) Gram matrix eigenvalues."""
    gram = m.T @ m if m.shape[0] >= m.shape[1] else m @ m.T
    evals, _ = eigh_jacobi(gram, sweeps=sweeps)
    return jnp.sqrt(jnp.maximum(evals, 0.0))
