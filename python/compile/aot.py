"""AOT export: lower every model/method variant to HLO text + manifest.

HLO *text* is the interchange format (NOT serialized protos): jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
runtime behind the rust `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

All artifacts are lowered with ``return_tuple=False`` so PJRT returns one
buffer per output and the rust coordinator can keep training state
device-resident across steps (execute_b chaining, DESIGN.md §7).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts [--force]
        [--only SUBSTR] [--skip-heavy]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import train, sketching
from .kernels.sketch_bwd import sketched_linear_bwd

# Which methods get a train-step artifact per model. MLP carries the full
# estimator zoo (Figs 1–2, 4); the larger architectures carry the retained
# subset (Fig 3) — spectral methods are MLP-only on this single-core testbed
# (DESIGN.md §6).
MLP_METHODS = list(sketching.ALL_METHODS)
BIG_METHODS = [
    "baseline",
    "per_element",
    "per_column",
    "per_sample",
    "l1",
    "l1_sq",
    "var",
    "ds",
]
GRADS_METHODS = ["baseline", "per_column", "per_sample", "l1", "ds", "rcs"]

BATCH = {"mlp": 128, "vit": 32, "bagnet": 32}

DTYPE_NAMES = {
    jnp.dtype("float32"): "f32",
    jnp.dtype("int32"): "s32",
    jnp.dtype("uint32"): "u32",
}


def to_hlo_text(fn, example_inputs) -> str:
    lowered = jax.jit(fn).lower(*example_inputs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _abstract(vals):
    out = []
    for v in vals:
        a = jax.api_util.shaped_abstractify(v)
        out.append({"dtype": DTYPE_NAMES[a.dtype], "shape": list(a.shape)})
    return out


def _spec_entry(name, spec, out_dir, force):
    """Lower one StepSpec → artifacts/<name>.hlo.txt, return manifest row."""
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    t0 = time.time()
    if force or not os.path.exists(path):
        text = to_hlo_text(spec.fn, spec.example_inputs)
        with open(path, "w") as f:
            f.write(text)
        status = f"lowered {len(text) // 1024}KiB in {time.time() - t0:.1f}s"
    else:
        status = "cached"
    outputs = jax.eval_shape(spec.fn, *spec.example_inputs)
    out_abs = [
        {"dtype": DTYPE_NAMES[o.dtype], "shape": list(o.shape)} for o in outputs
    ]
    in_abs = _abstract(spec.example_inputs)
    print(f"  {name}: {status}", flush=True)
    return {
        "name": name,
        "file": f"{name}.hlo.txt",
        "inputs": [{"name": n, **a} for n, a in zip(spec.input_names, in_abs)],
        "outputs": [{"name": n, **a} for n, a in zip(spec.output_names, out_abs)],
        "meta": spec.meta,
    }


def micro_specs():
    """Micro-function artifacts for rust↔python integration tests."""
    n = 64

    def pstar_fn(w, r):
        return (sketching.pstar_from_weights(w, r),)

    def sample_fn(key_bits, p):
        key = jax.random.wrap_key_data(key_bits)
        return (sketching.correlated_bernoulli(key, p),)

    def bwd_fn(g, colinv, rowinv, x, w):
        return sketched_linear_bwd(g, colinv, rowinv, x, w)

    key = jnp.zeros((2,), jnp.uint32)
    specs = [
        train.StepSpec(
            pstar_fn,
            ["w", "r"],
            ["p"],
            (jnp.ones((n,), jnp.float32), jnp.float32(8.0)),
            {"n": n},
        ),
        train.StepSpec(
            sample_fn,
            ["key", "p"],
            ["z"],
            (key, jnp.full((n,), 0.25, jnp.float32)),
            {"n": n},
        ),
        train.StepSpec(
            bwd_fn,
            ["g", "colinv", "rowinv", "x", "w"],
            ["dx", "dw", "db"],
            (
                jnp.ones((32, n), jnp.float32),
                jnp.ones((n,), jnp.float32),
                jnp.ones((32,), jnp.float32),
                jnp.ones((32, 48), jnp.float32),
                jnp.ones((n, 48), jnp.float32),
            ),
            {"b": 32, "dout": n, "din": 48},
        ),
    ]
    return ["micro_pstar", "micro_corr_sample", "micro_sketch_bwd"], specs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default=None, help="substring filter on names")
    ap.add_argument(
        "--skip-heavy",
        action="store_true",
        help="skip vit/bagnet variants (fast CI artifact builds)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    jobs = []  # (name, lazy builder)
    for model in ["mlp", "vit", "bagnet"]:
        if args.skip_heavy and model != "mlp":
            continue
        methods = MLP_METHODS if model == "mlp" else BIG_METHODS
        b = BATCH[model]
        jobs.append((f"init_{model}", lambda m=model: train.build_init(m)))
        jobs.append(
            (f"eval_{model}", lambda m=model, bb=b: train.build_eval_step(m, bb))
        )
        for method in methods:
            jobs.append(
                (
                    f"train_{model}_{method}",
                    lambda m=model, me=method, bb=b: train.build_train_step(
                        m, me, bb
                    ),
                )
            )
    for method in GRADS_METHODS:
        jobs.append(
            (
                f"grads_mlp_{method}",
                lambda me=method: train.build_grads("mlp", me, BATCH["mlp"]),
            )
        )
    mnames, mspecs = micro_specs()
    for n, s in zip(mnames, mspecs):
        jobs.append((n, lambda s=s: s))

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    old = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = {e["name"]: e for e in json.load(f)["artifacts"]}

    entries = []
    t0 = time.time()
    for name, builder in jobs:
        if args.only and args.only not in name:
            if name in old:
                entries.append(old[name])
            continue
        hlo_path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        if not args.force and os.path.exists(hlo_path) and name in old:
            entries.append(old[name])
            print(f"  {name}: cached")
            continue
        entries.append(_spec_entry(name, builder(), args.out_dir, args.force))

    with open(manifest_path, "w") as f:
        json.dump({"version": 1, "artifacts": entries}, f, indent=1)
    print(
        f"wrote {len(entries)} artifact entries to {manifest_path} "
        f"in {time.time() - t0:.0f}s",
        flush=True,
    )


if __name__ == "__main__":
    main()
