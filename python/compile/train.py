"""L2 training graph: loss, optimizers, and the AOT step builders.

Each builder returns a *flat-signature* function suitable for HLO export —
every pytree (params, optimizer state) is flattened to a fixed-order list of
arrays whose names/shapes are recorded in the artifact manifest, so the rust
coordinator can drive training generically.

Step signature (train):
    (params…, opt_state…, x, y, key_bits u32[2], p_budget f32, layer_mask
     f32[L], lr f32) → (params'…, opt_state'…, loss f32)

The sketch method is baked per artifact; budget, per-layer placement,
learning rate and seed are runtime inputs (DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .models import REGISTRY

# optimizer recipes per model, following §5 / Appendix B.2 (schedules are
# computed runtime-side in rust and fed through the `lr` input).
OPTIMIZERS = {
    "mlp": {"kind": "sgd", "clip": 1.0, "wd": 0.0},
    "bagnet": {"kind": "momentum", "mu": 0.9, "clip": 0.0, "wd": 1e-3},
    "vit": {"kind": "adamw", "b1": 0.9, "b2": 0.999, "clip": 0.0, "wd": 0.05},
}


def cross_entropy(logits, y):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _tree_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _leaf in flat:
        names.append("".join(str(p) for p in path).replace("['", ".").replace("']", "").lstrip("."))
    return names


def _clip_by_global_norm(grads, max_norm):
    if max_norm <= 0:
        return grads
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


# ---------------------------------------------------------------------------
# Optimizers over pytrees (state is itself a pytree, possibly empty)
# ---------------------------------------------------------------------------
def opt_init(cfg, params):
    kind = cfg["kind"]
    if kind == "sgd":
        return {}
    if kind == "momentum":
        return {"m": jax.tree_util.tree_map(jnp.zeros_like, params)}
    if kind == "adamw":
        return {
            "m": jax.tree_util.tree_map(jnp.zeros_like, params),
            "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.float32),
        }
    raise ValueError(kind)


def opt_update(cfg, params, grads, state, lr):
    kind = cfg["kind"]
    wd = cfg.get("wd", 0.0)
    if kind == "sgd":
        new = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        return new, state
    if kind == "momentum":
        mu = cfg["mu"]
        if wd:
            grads = jax.tree_util.tree_map(lambda g, p: g + wd * p, grads, params)
        m = jax.tree_util.tree_map(lambda m_, g: mu * m_ + g, state["m"], grads)
        new = jax.tree_util.tree_map(lambda p, m_: p - lr * m_, params, m)
        return new, {"m": m}
    if kind == "adamw":
        b1, b2, eps = cfg["b1"], cfg["b2"], 1e-8
        t = state["t"] + 1.0
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
        )
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
        )
        mhat = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1**t), m)
        vhat = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2**t), v)
        new = jax.tree_util.tree_map(
            lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p),
            params,
            mhat,
            vhat,
        )
        return new, {"m": m, "v": v, "t": t}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
class StepSpec:
    """Flat-signature function + metadata for AOT export."""

    def __init__(self, fn, input_names, output_names, example_inputs, meta):
        self.fn = fn
        self.input_names = input_names
        self.output_names = output_names
        self.example_inputs = example_inputs
        self.meta = meta


def _example_batch(model_name: str, batch: int):
    mod = REGISTRY[model_name]
    x = jnp.zeros((batch,) + mod.INPUT_SHAPE, jnp.float32)
    y = jnp.zeros((batch,), jnp.int32)
    return x, y


def _loss_fn(model_name: str, method: str):
    mod = REGISTRY[model_name]

    def loss(params, x, y, key, p_budget, layer_mask):
        logits = mod.apply(params, x, key, p_budget, layer_mask, method)
        return cross_entropy(logits, y)

    return loss


def build_train_step(model_name: str, method: str, batch: int) -> StepSpec:
    """One SGD/momentum/AdamW step with the chosen sketched backward."""
    mod = REGISTRY[model_name]
    cfg = OPTIMIZERS[model_name]
    params0 = mod.init(jax.random.key(0))
    opt0 = opt_init(cfg, params0)
    p_leaves, p_def = jax.tree_util.tree_flatten(params0)
    o_leaves, o_def = jax.tree_util.tree_flatten(opt0)
    loss_fn = _loss_fn(model_name, method)
    n_p = len(p_leaves)
    n_o = len(o_leaves)

    def step(*args):
        params = jax.tree_util.tree_unflatten(p_def, args[:n_p])
        opt = jax.tree_util.tree_unflatten(o_def, args[n_p : n_p + n_o])
        x, y, key_bits, p_budget, layer_mask, lr = args[n_p + n_o :]
        key = jax.random.wrap_key_data(key_bits)
        lval, grads = jax.value_and_grad(loss_fn)(
            params, x, y, key, p_budget, layer_mask
        )
        grads = _clip_by_global_norm(grads, cfg.get("clip", 0.0))
        params, opt = opt_update(cfg, params, grads, opt, lr)
        return tuple(jax.tree_util.tree_leaves(params)) + tuple(
            jax.tree_util.tree_leaves(opt)
        ) + (lval,)

    x, y = _example_batch(model_name, batch)
    lm = jnp.ones((mod.NUM_SKETCHED,), jnp.float32)
    example = (
        tuple(p_leaves)
        + tuple(o_leaves)
        + (
            x,
            y,
            jnp.zeros((2,), jnp.uint32),
            jnp.float32(1.0),
            lm,
            jnp.float32(0.1),
        )
    )
    pnames = ["param." + n for n in _tree_names(params0)]
    onames = ["opt." + n for n in _tree_names(opt0)]
    input_names = pnames + onames + ["x", "y", "key", "p_budget", "layer_mask", "lr"]
    output_names = pnames + onames + ["loss"]
    meta = {
        "model": model_name,
        "method": method,
        "batch": batch,
        "num_params": n_p,
        "num_opt": n_o,
        "num_sketched": mod.NUM_SKETCHED,
        "optimizer": cfg["kind"],
    }
    return StepSpec(step, input_names, output_names, example, meta)


def build_eval_step(model_name: str, batch: int) -> StepSpec:
    """(params…, x, y) → (loss_sum, correct_count) on one batch."""
    mod = REGISTRY[model_name]
    params0 = mod.init(jax.random.key(0))
    p_leaves, p_def = jax.tree_util.tree_flatten(params0)
    n_p = len(p_leaves)
    lm = jnp.zeros((mod.NUM_SKETCHED,), jnp.float32)

    def step(*args):
        params = jax.tree_util.tree_unflatten(p_def, args[:n_p])
        x, y = args[n_p:]
        key = jax.random.key(0)
        logits = mod.apply(params, x, key, jnp.float32(1.0), lm, "baseline")
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss_sum = -jnp.sum(jnp.take_along_axis(logp, y[:, None], axis=1))
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss_sum, correct

    x, y = _example_batch(model_name, batch)
    example = tuple(p_leaves) + (x, y)
    pnames = ["param." + n for n in _tree_names(params0)]
    meta = {"model": model_name, "batch": batch, "num_params": n_p}
    return StepSpec(step, pnames + ["x", "y"], ["loss_sum", "correct"], example, meta)


def build_init(model_name: str) -> StepSpec:
    """(key) → (params…, opt_state…) — keeps init logic in python."""
    mod = REGISTRY[model_name]
    cfg = OPTIMIZERS[model_name]

    def fn(key_bits):
        key = jax.random.wrap_key_data(key_bits)
        params = mod.init(key)
        opt = opt_init(cfg, params)
        return tuple(jax.tree_util.tree_leaves(params)) + tuple(
            jax.tree_util.tree_leaves(opt)
        )

    params0 = mod.init(jax.random.key(0))
    opt0 = opt_init(cfg, params0)
    pnames = ["param." + n for n in _tree_names(params0)]
    onames = ["opt." + n for n in _tree_names(opt0)]
    meta = {
        "model": model_name,
        "num_params": len(pnames),
        "num_opt": len(onames),
    }
    return StepSpec(
        fn, ["key"], pnames + onames, (jnp.zeros((2,), jnp.uint32),), meta
    )


def build_grads(model_name: str, method: str, batch: int) -> StepSpec:
    """(params…, x, y, key, p_budget, layer_mask) → flat gradient vector.

    Used by the variance experiments (Prop 2.2 validation): rust executes this
    repeatedly with fresh keys on a fixed batch and measures E‖ĝ − g‖².
    """
    mod = REGISTRY[model_name]
    params0 = mod.init(jax.random.key(0))
    p_leaves, p_def = jax.tree_util.tree_flatten(params0)
    n_p = len(p_leaves)
    loss_fn = _loss_fn(model_name, method)

    def step(*args):
        params = jax.tree_util.tree_unflatten(p_def, args[:n_p])
        x, y, key_bits, p_budget, layer_mask = args[n_p:]
        key = jax.random.wrap_key_data(key_bits)
        grads = jax.grad(loss_fn)(params, x, y, key, p_budget, layer_mask)
        flat = jnp.concatenate(
            [g.reshape(-1) for g in jax.tree_util.tree_leaves(grads)]
        )
        return (flat,)

    x, y = _example_batch(model_name, batch)
    lm = jnp.ones((mod.NUM_SKETCHED,), jnp.float32)
    example = tuple(p_leaves) + (
        x,
        y,
        jnp.zeros((2,), jnp.uint32),
        jnp.float32(1.0),
        lm,
    )
    pnames = ["param." + n for n in _tree_names(params0)]
    dim = sum(int(l.size) for l in p_leaves)
    meta = {
        "model": model_name,
        "method": method,
        "batch": batch,
        "grad_dim": dim,
        "num_params": n_p,
        "num_sketched": mod.NUM_SKETCHED,
    }
    return StepSpec(
        step,
        pnames + ["x", "y", "key", "p_budget", "layer_mask"],
        ["grads"],
        example,
        meta,
    )
