"""L1 Pallas kernels: the sketched linear backward pass.

This is the compute hot-spot of the paper: given the (possibly sketched)
output gradient of a linear layer ``y = x Wᵀ + b``, produce

    dX = Ĝ · W          (B, d_in)
    dW = Ĝᵀ · X         (d_out, d_in)
    db = Σ_b Ĝ[b, :]    (d_out,)

where ``Ĝ = G ⊙ rowinv[:, None] ⊙ colinv[None, :]`` fuses the unbiased
mask-and-rescale of §4 into the tile loads (one VPU pass per tile), so the
mask never materializes in HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): tiles default to 128×128 — the
MXU systolic shape — and each grid step keeps one Ĝ tile + one W/X tile in
VMEM; the reduction axis runs innermost so partial accumulators stay resident
in the output VMEM block. Column-budget sparsity corresponds to dropping
colinv≈0 column-blocks from the grid (HBM→VMEM traffic savings); in this
repo the CPU interpret path always materializes the full grid and the FLOP
savings are modeled in the rust cost model (DESIGN.md §6).

``interpret=True`` is mandatory here: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU-only box; see module docstring.


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_block(dim: int, want: int) -> int:
    """Block size: the requested MXU-friendly tile, clamped for small dims."""
    return min(want, _ceil_to(dim, 8))


def _pad2(a, rows, cols):
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def _pad1(a, n):
    return jnp.pad(a, ((0, n - a.shape[0]),))


# ---------------------------------------------------------------------------
# dX = Ĝ W  — grid (B/bB, d_in/bD, d_out/bK), accumulate over k.
# ---------------------------------------------------------------------------
def _dx_kernel(g_ref, colinv_ref, rowinv_ref, w_ref, o_ref):
    k = pl.program_id(2)
    ghat = g_ref[...] * colinv_ref[...][None, :] * rowinv_ref[...][:, None]
    acc = jnp.dot(ghat, w_ref[...], preferred_element_type=o_ref.dtype)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += acc


# ---------------------------------------------------------------------------
# dW = Ĝᵀ X — grid (d_out/bO, d_in/bD, B/bK), accumulate over k.
# ---------------------------------------------------------------------------
def _dw_kernel(g_ref, colinv_ref, rowinv_ref, x_ref, o_ref):
    k = pl.program_id(2)
    ghat = g_ref[...] * colinv_ref[...][None, :] * rowinv_ref[...][:, None]
    acc = jnp.dot(ghat.T, x_ref[...], preferred_element_type=o_ref.dtype)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += acc


# ---------------------------------------------------------------------------
# db = Σ_b Ĝ — grid (d_out/bO, B/bK), accumulate over k.
# ---------------------------------------------------------------------------
def _db_kernel(g_ref, colinv_ref, rowinv_ref, o_ref):
    k = pl.program_id(1)
    ghat = g_ref[...] * colinv_ref[...][None, :] * rowinv_ref[...][:, None]
    acc = jnp.sum(ghat, axis=0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += acc


def sketched_linear_bwd(
    g: jax.Array,
    colinv: jax.Array,
    rowinv: jax.Array,
    x: jax.Array,
    w: jax.Array,
    *,
    block_b: int = 128,
    block_dout: int = 128,
    block_din: int = 128,
):
    """Sketched backward of ``y = x Wᵀ + b``; returns (dX, dW, db).

    Shapes: g (B, d_out), colinv (d_out,), rowinv (B,), x (B, d_in),
    w (d_out, d_in). Ragged shapes are zero-padded to tile multiples (zeros
    are absorbing for all three products) and sliced back.
    """
    bsz, dout = g.shape
    din = x.shape[1]
    dtype = g.dtype

    bb = _pick_block(bsz, block_b)
    bo = _pick_block(dout, block_dout)
    bd = _pick_block(din, block_din)
    pb, po, pd = _ceil_to(bsz, bb), _ceil_to(dout, bo), _ceil_to(din, bd)

    gp = _pad2(g, pb, po)
    xp = _pad2(x, pb, pd)
    wp = _pad2(w, po, pd)
    cp = _pad1(colinv, po)
    rp = _pad1(rowinv, pb)

    nb, no, nd, nkb, nko = pb // bb, po // bo, pd // bd, pb // bb, po // bo

    dx = pl.pallas_call(
        _dx_kernel,
        grid=(nb, nd, nko),
        in_specs=[
            pl.BlockSpec((bb, bo), lambda i, j, k: (i, k)),
            pl.BlockSpec((bo,), lambda i, j, k: (k,)),
            pl.BlockSpec((bb,), lambda i, j, k: (i,)),
            pl.BlockSpec((bo, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, pd), dtype),
        interpret=INTERPRET,
    )(gp, cp, rp, wp)

    dw = pl.pallas_call(
        _dw_kernel,
        grid=(no, nd, nkb),
        in_specs=[
            pl.BlockSpec((bb, bo), lambda i, j, k: (k, i)),
            pl.BlockSpec((bo,), lambda i, j, k: (i,)),
            pl.BlockSpec((bb,), lambda i, j, k: (k,)),
            pl.BlockSpec((bb, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bo, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((po, pd), dtype),
        interpret=INTERPRET,
    )(gp, cp, rp, xp)

    db = pl.pallas_call(
        _db_kernel,
        grid=(no, nkb),
        in_specs=[
            pl.BlockSpec((bb, bo), lambda i, k: (k, i)),
            pl.BlockSpec((bo,), lambda i, k: (i,)),
            pl.BlockSpec((bb,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bo,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((po,), dtype),
        interpret=INTERPRET,
    )(gp, cp, rp)

    return dx[:bsz, :din], dw[:dout, :din], db[:dout]


def vmem_bytes(block_b: int, block_dout: int, block_din: int, dtype_bytes: int = 4):
    """Estimated VMEM residency of one dX-grid step (for DESIGN.md §Perf)."""
    g_tile = block_b * block_dout
    w_tile = block_dout * block_din
    o_tile = block_b * block_din
    vecs = block_b + block_dout
    return (g_tile + w_tile + o_tile + vecs) * dtype_bytes
