"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every Pallas kernel in this package has a reference implementation here; the
pytest/hypothesis suite asserts ``assert_allclose(kernel, ref)`` over swept
shapes and dtypes (python/tests/test_kernels.py).
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_sketched_linear_bwd(g, colinv, rowinv, x, w):
    """Reference for kernels.sketch_bwd.sketched_linear_bwd."""
    ghat = g * colinv[None, :] * rowinv[:, None]
    dx = ghat @ w
    dw = ghat.T @ x
    db = jnp.sum(ghat, axis=0)
    return dx, dw, db


def ref_column_stats(g):
    """Reference for kernels.scores.column_stats."""
    return (
        jnp.sum(jnp.abs(g), axis=0),
        jnp.sum(g * g, axis=0),
        jnp.sum(g, axis=0),
    )


def ref_linear_fwd(x, w, b):
    """Row-convention linear forward (Appendix C.1): y = x Wᵀ + b."""
    return x @ w.T + b
