"""L1 Pallas kernel: fused per-column gradient statistics.

One pass over the (B, d_out) output-gradient matrix produces, per column,
``Σ|g|``, ``Σ g²`` and ``Σ g`` — from which every coordinate proxy of §4.2
(ℓ1, ℓ2, Var and their squares; the Γ_B diagonal of DS) derives without
touching G again. On TPU this is a single HBM read of G per layer per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .sketch_bwd import INTERPRET, _ceil_to, _pick_block, _pad2


def _stats_kernel(g_ref, abs_ref, sq_ref, sum_ref):
    k = pl.program_id(1)
    g = g_ref[...]

    a = jnp.sum(jnp.abs(g), axis=0)
    s = jnp.sum(g * g, axis=0)
    m = jnp.sum(g, axis=0)

    @pl.when(k == 0)
    def _init():
        abs_ref[...] = a
        sq_ref[...] = s
        sum_ref[...] = m

    @pl.when(k > 0)
    def _acc():
        abs_ref[...] += a
        sq_ref[...] += s
        sum_ref[...] += m


def column_stats(g: jax.Array, *, block_b: int = 128, block_dout: int = 128):
    """Per-column (|g| sum, g² sum, g sum) of a (B, d_out) matrix."""
    bsz, dout = g.shape
    bb = _pick_block(bsz, block_b)
    bo = _pick_block(dout, block_dout)
    pb, po = _ceil_to(bsz, bb), _ceil_to(dout, bo)
    gp = _pad2(g, pb, po)
    out_shape = [jax.ShapeDtypeStruct((po,), g.dtype)] * 3
    absums, sqsums, sums = pl.pallas_call(
        _stats_kernel,
        grid=(po // bo, pb // bb),
        in_specs=[pl.BlockSpec((bb, bo), lambda i, k: (k, i))],
        out_specs=[pl.BlockSpec((bo,), lambda i, k: (i,))] * 3,
        out_shape=out_shape,
        interpret=INTERPRET,
    )(gp)
    return absums[:dout], sqsums[:dout], sums[:dout]


def fused_scores(method: str, g: jax.Array, w_mat: jax.Array) -> jax.Array:
    """Column importance weights via the fused stats kernel (mirrors
    ``sketching.column_scores`` — the pure-jnp fallback/oracle)."""
    bsz = g.shape[0]
    absums, sqsums, sums = column_stats(g)
    if method in ("l1", "l1_ind"):
        return absums * absums
    if method == "l1_sq":
        return (absums * absums) ** 2
    if method == "l2":
        return sqsums
    if method == "l2_sq":
        return sqsums**2
    if method == "var":
        return sqsums / bsz - (sums / bsz) ** 2
    if method == "var_sq":
        return (sqsums / bsz - (sums / bsz) ** 2) ** 2
    if method == "ds":
        jtj_diag = jnp.sum(w_mat * w_mat, axis=1)
        return (sqsums / bsz) * jtj_diag
    raise ValueError(f"unknown coordinate method {method!r}")
