"""L2 building blocks: layers whose backward pass is a randomized VJP.

The central export is :func:`sketched_linear` — a linear layer whose forward
is exact and whose backward replaces the exact VJPs by the paper's unbiased
randomized estimators (method chosen statically, budget/enable/key traced).

Plumbing notes
--------------
* PRNG keys cross the ``jax.custom_vjp`` boundary as **f32-bitcast uint32
  pairs** (``key_bits``): integer primals would demand float0 cotangents,
  while f32 bits get ordinary zero cotangents. Use :func:`key_to_bits` /
  :func:`bits_to_key`.
* Inputs with leading batch/token/pixel axes are flattened to rows for the
  sketch — exactly the paper's treatment of 1×1 convolutions and token MLPs
  as linear layers over a widened batch.
* ``enable`` ∈ {0., 1.} gates the sketch per layer (Fig 4 location ablation)
  by blending the mask with all-ones — numerically exact when 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import sketching
from .kernels.sketch_bwd import sketched_linear_bwd as pallas_bwd


def key_to_bits(key: jax.Array) -> jax.Array:
    """Typed PRNG key → f32[2] bit pattern (safe custom_vjp primal)."""
    data = jax.random.key_data(key).astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(data, jnp.float32)

def bits_to_key(bits: jax.Array) -> jax.Array:
    """Inverse of :func:`key_to_bits`."""
    data = jax.lax.bitcast_convert_type(bits, jnp.uint32)
    return jax.random.wrap_key_data(data)


@functools.lru_cache(maxsize=None)
def _make_sketched_linear(method: str, use_pallas: bool):
    """Build (and cache) the custom-VJP linear for one sketch method."""

    @jax.custom_vjp
    def f(x, w, b, key_bits, p_budget, enable):
        del key_bits, p_budget, enable
        return x @ w.T + b

    def fwd(x, w, b, key_bits, p_budget, enable):
        return f(x, w, b, key_bits, p_budget, enable), (x, w, key_bits, p_budget, enable)

    def bwd(res, gy):
        x, w, key_bits, p_budget, enable = res
        lead = gy.shape[:-1]
        dout = gy.shape[-1]
        din = x.shape[-1]
        g2 = gy.reshape((-1, dout))
        x2 = x.reshape((-1, din))
        key = bits_to_key(key_bits)
        zeros_bits = jnp.zeros_like(key_bits)
        zero = jnp.zeros_like(p_budget)

        if method == "per_element":
            # Algorithm 3: independent element masks on W and X.
            kw, kx = jax.random.split(key)
            p = p_budget
            mw = sketching.independent_bernoulli(kw, jnp.full(w.shape, p, w.dtype))
            mx = sketching.independent_bernoulli(kx, jnp.full(x2.shape, p, x2.dtype))
            mw = enable * mw / p + (1.0 - enable)
            mx = enable * mx / p + (1.0 - enable)
            dx = (g2 @ (w * mw)).reshape(x.shape)
            dw = g2.T @ (x2 * mx)
            db = jnp.sum(g2, axis=0)
            return dx, dw, db, zeros_bits, zero, zero

        ghat, colinv, rowinv = sketching.sketch_ghat(
            method, g2, w, key, p_budget, enable
        )
        if use_pallas:
            # Wide row counts (1×1 convs fold pixels into rows) want taller
            # tiles: fewer grid steps amortize the per-tile loop overhead of
            # the interpret path and map to deeper HBM→VMEM pipelining on TPU.
            bb = 512 if g2.shape[0] >= 2048 else 128
            dx2, dw, db = pallas_bwd(ghat, colinv, rowinv, x2, w, block_b=bb)
        else:
            gh = ghat * colinv[None, :] * rowinv[:, None]
            dx2, dw, db = gh @ w, gh.T @ x2, jnp.sum(gh, axis=0)
        return dx2.reshape(x.shape), dw, db, zeros_bits, zero, zero

    f.defvjp(fwd, bwd)
    return f


def sketched_linear(
    method: str,
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    key: jax.Array,
    p_budget: jax.Array,
    enable: jax.Array,
    use_pallas: bool = True,
) -> jax.Array:
    """Linear layer ``y = x Wᵀ + b`` with an unbiased randomized backward.

    ``method`` ∈ sketching.ALL_METHODS (static); ``p_budget`` the kept
    fraction (traced scalar); ``enable`` the per-layer sketch gate (traced
    scalar in {0, 1}).
    """
    f = _make_sketched_linear(method, use_pallas)
    return f(x, w, b, key_to_bits(key), p_budget, enable)


# ---------------------------------------------------------------------------
# Exact layers (never sketched — paper sketches only linear/1×1-conv layers)
# ---------------------------------------------------------------------------
def relu(x):
    return jnp.maximum(x, 0.0)


def gelu(x):
    return jax.nn.gelu(x)


def layernorm(x, gamma, beta, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attention(q, k, v, n_heads: int):
    """Multi-head self-attention on (B, T, D) tensors (exact backward)."""
    bsz, t, d = q.shape
    hd = d // n_heads

    def split(a):
        return a.reshape(bsz, t, n_heads, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    logits = jnp.einsum("bhtd,bhsd->bhts", qh, kh) / jnp.sqrt(float(hd))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, vh)
    return out.transpose(0, 2, 1, 3).reshape(bsz, t, d)


def patchify(images, patch: int):
    """(B, H, W, C) → (B, T, patch·patch·C) non-overlapping patches."""
    bsz, h, w, c = images.shape
    gh, gw = h // patch, w // patch
    x = images.reshape(bsz, gh, patch, gw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(bsz, gh * gw, patch * patch * c)


def avgpool2x2(x):
    """(B, H, W, C) → (B, H/2, W/2, C) mean pooling."""
    bsz, h, w, c = x.shape
    return x.reshape(bsz, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))
